//! Per-request quantized KV cache — the Fig. 4 storage layout, held as a
//! **page table over pool-leased storage** (kvcache::pool):
//!
//! * three-tier quantized key window (BF16 / packed u4 / packed u2 columns,
//!   grouped scales/zeros), one page per quantization group per head,
//! * per-token quantized value window (same pages),
//! * the full-precision residual buffer X_R (flat, off-pool — it is small,
//!   bounded, and recycled in place),
//! * per-head channel permutation `idx` + the running I_d accumulator.
//!
//! Storage is leased one group-page at a time on `store_key_window` /
//! `flush` / `load_prefill` and returned to the pool on eviction, error
//! unwinding, or request retirement (lease `Drop`) — a request's footprint
//! is proportional to what it holds, never to window capacity. Group-
//! aligned eviction is a page-table splice (kvcache::eviction). The decode
//! hot path (`scores_into` / `values_accumulate_into`) and the engine's
//! batch gathers stream page by page, so the fused zero-alloc decode of
//! PR 2 is unchanged in cost.
//!
//! The channel plan (which channels land in which tier) is decided at the
//! first quantization event from (prefill I_d) × (window S_d) and reused for
//! later windows: the decode graph takes one `idx` input per head, so the
//! permutation must be stable across a request. I_d keeps accumulating and
//! is re-consulted if the plan is recomputed via `replan()` (used by the
//! refresh ablation).
//!
//! # Cross-request prefix sharing (the CoW seam)
//!
//! A page table may begin with **shared read-only prefix pages**
//! ([`crate::kvcache::pool::PageRef::Shared`]) adopted from a
//! [`crate::kvcache::radix::RadixTree`] probe: N requests over the same
//! prompt (or the same prompt *prefix*) hold refcounted references to ONE
//! set of quantized pages instead of quantizing N private copies. The seam
//! contract:
//!
//! * **immutability precondition** — a flushed page is never written again
//!   (appends mutate the residual; later flushes lease *new* pages), so
//!   sharing changes provenance, not a single stored bit. Writes through a
//!   shared [`PageRef`](crate::kvcache::pool::PageRef) panic. Radix nodes
//!   hold exactly such flushed pages, one `(layer, head)` set per G-token
//!   group.
//! * **full hits are bit-exact, partial hits are frozen-plan** — the
//!   channel plan and the per-group scale blocks are functions of the
//!   entire quantized window *and* the whole prompt's |Q| statistics, so
//!   bit-exact adoption requires the entire prompt to match
//!   ([`crate::kvcache::pool::prompt_chain_key`]); a full-hit tail carries
//!   the plans, |Q| state, residual tail, and last logits, letting the
//!   consumer skip the prefill compute outright. A **partial** hit
//!   ([`crate::kvcache::radix::PrefixProbe::Partial`]) instead adopts the
//!   producer's *frozen* plan and |Q| state for the matched groups and
//!   resumes chunked prefill from the divergence seam
//!   ([`RequestCache::begin_prefill_from`] /
//!   [`RequestCache::store_prefill_layer_from`]): the tail quantizes under
//!   the producer's channel permutation with tail-window scales, a
//!   bounded, per-method-measured approximation
//!   (`harness::profiling::frozen_plan_error`).
//! * **CoW at the seam** — divergence past the shared region copies
//!   nothing: the first flush (or resumed-prefill store) after
//!   installation leases private pages and appends them after the shared
//!   ones. Evicting a shared page only drops this request's table entry
//!   and reference; the page returns to the pool when its last holder
//!   (co-tenant or radix node) lets go.
//!
//! Every read path (`scores_into`, `values_accumulate_into`, `dequant_*`,
//! `copy_field_*`, `contiguous`) streams through shared and private pages
//! identically, so the fused zero-alloc decode is unchanged in cost.

use anyhow::{bail, Result};

use crate::model::config::{CacheConfig, ModelConfig};
use crate::quant::methods::Method;
use crate::quant::packing;
use crate::quant::rotation;
use crate::quant::salience::QueryStats;
use crate::quant::window::{self, TierSpec};

use super::pool::{KvPool, PageLayout, PageLease, PageRef, SharedLease};
use super::radix::{PrefixMatch, PrefixPayload, RadixTree};
use super::residual::ResidualBuffer;

/// Tier region selector for page-streamed gathers (`copy_field_f32` /
/// `copy_field_u8`) — the engine maps decode-graph input names onto these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageField {
    K16,
    K4s,
    K4z,
    K2s,
    K2z,
    Vs,
    Vz,
    Vfull,
    K4p,
    K2p,
    Vp,
}

/// The pre-pool contiguous layout materialized from a page table — the
/// bit-identity oracle for tests (`tests/paged_cache.rs`): paged storage
/// must read back exactly what the old flat capacity-sized buffers held
/// for the leased region.
#[derive(Clone, Debug, PartialEq)]
pub struct ContiguousHead {
    pub k16: Vec<f32>,
    pub k4p: Vec<u8>,
    pub k4s: Vec<f32>,
    pub k4z: Vec<f32>,
    pub k2p: Vec<u8>,
    pub k2s: Vec<f32>,
    pub k2z: Vec<f32>,
    pub vp: Vec<u8>,
    pub vs: Vec<f32>,
    pub vz: Vec<f32>,
    pub vfull: Vec<f32>,
}

/// One (layer, kv-head) cache shard: a page table of leased group-pages.
pub struct HeadState {
    pub spec: TierSpec,
    pub d: usize,
    pub capacity: usize,
    pub group: usize,
    /// Channel permutation (tier-concatenated); identity until planned.
    pub idx: Vec<i32>,
    pub planned: bool,
    /// Per-spec offsets into a page's arenas.
    pub layout: PageLayout,
    /// pages[g] holds tokens [g*G, (g+1)*G) across every tier buffer —
    /// private (writable) leases, or shared read-only prefix pages.
    pub(crate) pages: Vec<PageRef>,
    pool: KvPool,
    pub res: ResidualBuffer,
    pub qstats: QueryStats,
    /// Fault-draw context for this head's pool leases: a deterministic
    /// function of the owning request's fault key and this head's (layer,
    /// kv-head) position, set by `RequestCache::set_fault_key`. Together
    /// with `lease_seq` it makes every lease-denial draw a pure function
    /// of request identity × lease ordinal — independent of which worker
    /// thread runs the flush (see `util::faults`).
    fault_ctx: u64,
    /// This head's own monotone lease ordinal (advances per lease attempt).
    lease_seq: u64,
}

impl HeadState {
    /// Value-side channel group: values group along d_head, so G clamps to
    /// d (relevant only for the Table 5 G-sweep where G > d_head).
    pub fn vgroup(&self) -> usize {
        self.group.min(self.d)
    }

    fn new(spec: TierSpec, d: usize, cc: &CacheConfig, pool: &KvPool) -> Self {
        let layout = PageLayout::new(spec, d, cc.group);
        assert!(
            pool.fits(&layout),
            "pool pages too small for spec {spec:?} (layout needs {}f32+{}B)",
            layout.f_len,
            layout.b_len
        );
        HeadState {
            spec,
            d,
            capacity: cc.capacity,
            group: cc.group,
            idx: (0..d as i32).collect(),
            planned: false,
            layout,
            pages: Vec::with_capacity(cc.capacity / cc.group),
            pool: pool.clone(),
            res: ResidualBuffer::new(cc.residual, d),
            qstats: QueryStats::new(d),
            fault_ctx: 0,
            lease_seq: 0,
        }
    }

    /// Pages this head currently leases.
    pub fn pages_leased(&self) -> usize {
        self.pages.len()
    }

    /// Pages in this head's table that are shared prefix pages.
    pub fn shared_pages(&self) -> usize {
        self.pages.iter().filter(|p| p.is_shared()).count()
    }

    /// Convert the first `groups` table entries to the shared form
    /// (idempotent) and return one extra reference per page for the prefix
    /// index. Cold path — registration happens once per distinct prompt.
    pub(crate) fn share_prefix(&mut self, groups: usize) -> Vec<SharedLease> {
        debug_assert!(groups <= self.pages.len());
        let mut refs = Vec::with_capacity(groups);
        let tail = self.pages.split_off(groups);
        let head = std::mem::take(&mut self.pages);
        self.pages = head
            .into_iter()
            .map(|p| {
                let (p, s) = p.into_shared();
                refs.push(s);
                p
            })
            .collect();
        self.pages.extend(tail);
        refs
    }

    /// Write a quantized key window into pool pages at token offset `at`
    /// (`at` and `w.t` must be group-aligned), leasing pages as needed.
    fn store_key_window(&mut self, w: &window::KeyWindow, at: usize) -> Result<()> {
        let g = self.group;
        debug_assert_eq!(at % g, 0);
        debug_assert_eq!(w.t % g, 0);
        let lay = self.layout;
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let g0 = at / g;
        let gn = w.t / g;
        debug_assert!(g0 <= self.pages.len(), "non-contiguous page write");
        while self.pages.len() < g0 + gn {
            // divergence past a shared prefix lands here: NEW private pages
            // are leased and appended — shared pages are never written. The
            // keyed draw keeps injected lease denials replay-deterministic
            // whatever worker thread runs this flush.
            let key = crate::util::faults::draw_key(self.fault_ctx, self.lease_seq);
            self.lease_seq += 1;
            self.pages.push(PageRef::Private(self.pool.lease_keyed(key)?));
        }
        for gi in 0..gn {
            let page = self.pages[g0 + gi].page_mut();
            page.f[lay.k16r()].copy_from_slice(&w.k16[gi * g * n16..(gi + 1) * g * n16]);
            if n4 > 0 {
                page.b[lay.k4pr()].copy_from_slice(&w.k4p[gi * g * n4 / 2..(gi + 1) * g * n4 / 2]);
                page.f[lay.k4sr()].copy_from_slice(&w.k4s[gi * n4..(gi + 1) * n4]);
                page.f[lay.k4zr()].copy_from_slice(&w.k4z[gi * n4..(gi + 1) * n4]);
            }
            if n2 > 0 {
                page.b[lay.k2pr()].copy_from_slice(&w.k2p[gi * g * n2 / 4..(gi + 1) * g * n2 / 4]);
                page.f[lay.k2sr()].copy_from_slice(&w.k2s[gi * n2..(gi + 1) * n2]);
                page.f[lay.k2zr()].copy_from_slice(&w.k2z[gi * n2..(gi + 1) * n2]);
            }
        }
        Ok(())
    }

    /// Record integrity checksums for the pages covering tokens
    /// `[at, at+t)` — called once a flush has completed BOTH the key and
    /// value stores for those pages (after which they are never written
    /// again; see the pool's sharing docs). `KvPool::verify_page` checks
    /// against these seals on scrub and restore.
    fn seal_groups(&self, at: usize, t: usize) {
        let g0 = at / self.group;
        let gn = t / self.group;
        for p in &self.pages[g0..g0 + gn] {
            self.pool.seal_page(p.page());
        }
    }

    /// Write a quantized value window into the pages leased by the
    /// matching key window (keys store first — see `quantize_into`).
    fn store_value_window(&mut self, w: &window::ValueWindow, at: usize) {
        let g = self.group;
        let (d, gv) = (self.d, self.vgroup());
        debug_assert_eq!(at % g, 0);
        debug_assert_eq!(w.t % g, 0);
        let lay = self.layout;
        let g0 = at / g;
        let gn = w.t / g;
        debug_assert!(g0 + gn <= self.pages.len(), "value write beyond leased pages");
        for gi in 0..gn {
            let page = self.pages[g0 + gi].page_mut();
            if self.spec.v_bits == 16 {
                page.f[lay.vfullr()].copy_from_slice(&w.vfull[gi * g * d..(gi + 1) * g * d]);
            } else {
                let b = self.spec.v_bits;
                page.b[lay.vpr()]
                    .copy_from_slice(&w.vp[gi * g * d * b / 8..(gi + 1) * g * d * b / 8]);
                page.f[lay.vsr()].copy_from_slice(&w.vs[gi * g * d / gv..(gi + 1) * g * d / gv]);
                page.f[lay.vzr()].copy_from_slice(&w.vz[gi * g * d / gv..(gi + 1) * g * d / gv]);
            }
        }
    }

    /// Dequantize the first `qlen` key rows back to f32 in ORIGINAL channel
    /// order (rotated space) — the reference-path view.
    pub fn dequant_keys(&self, qlen: usize) -> Vec<f32> {
        let (d, g) = (self.d, self.group);
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        debug_assert!(qlen <= self.pages.len() * g);
        let mut out = vec![0f32; qlen * d];
        let mut row4 = Vec::with_capacity(n4);
        let mut row2 = Vec::with_capacity(n2);
        let mut tok = 0;
        while tok < qlen {
            let grp = tok / g;
            let pv = self.layout.view(self.pages[grp].page());
            let end = ((grp + 1) * g).min(qlen);
            for t in tok..end {
                let ti = t - grp * g;
                for j in 0..n16 {
                    out[t * d + self.idx[j] as usize] = pv.k16[ti * n16 + j];
                }
                row4.clear();
                packing::unpack_u4(&pv.k4p[ti * n4 / 2..(ti + 1) * n4 / 2], &mut row4);
                for j in 0..n4 {
                    out[t * d + self.idx[n16 + j] as usize] =
                        row4[j] as f32 * pv.k4s[j] + pv.k4z[j];
                }
                row2.clear();
                packing::unpack_u2(&pv.k2p[ti * n2 / 4..(ti + 1) * n2 / 4], &mut row2);
                for j in 0..n2 {
                    out[t * d + self.idx[n16 + n4 + j] as usize] =
                        row2[j] as f32 * pv.k2s[j] + pv.k2z[j];
                }
            }
            tok = end;
        }
        out
    }

    /// Dequantize the first `qlen` value rows.
    pub fn dequant_values(&self, qlen: usize) -> Vec<f32> {
        let (d, g) = (self.d, self.group);
        let gv = self.vgroup();
        debug_assert!(qlen <= self.pages.len() * g);
        let b = self.spec.v_bits;
        let ng = d / gv;
        let mut out = vec![0f32; qlen * d];
        let mut row = Vec::with_capacity(d);
        let mut tok = 0;
        while tok < qlen {
            let grp = tok / g;
            let pv = self.layout.view(self.pages[grp].page());
            let end = ((grp + 1) * g).min(qlen);
            for t in tok..end {
                let ti = t - grp * g;
                if b == 16 {
                    out[t * d..(t + 1) * d].copy_from_slice(&pv.vfull[ti * d..(ti + 1) * d]);
                    continue;
                }
                row.clear();
                if b == 4 {
                    packing::unpack_u4(&pv.vp[ti * d / 2..(ti + 1) * d / 2], &mut row);
                } else {
                    packing::unpack_u2(&pv.vp[ti * d / 4..(ti + 1) * d / 4], &mut row);
                }
                for ch in 0..d {
                    let s = pv.vs[ti * ng + ch / gv];
                    let z = pv.vz[ti * ng + ch / gv];
                    out[t * d + ch] = row[ch] as f32 * s + z;
                }
            }
            tok = end;
        }
        out
    }

    /// Fused attention scores over the packed quantized window:
    /// `out[t] = scale * q·dequant(k_t)` streamed **page by page from the
    /// packed tier buffers** — no f32 window is materialized. Per page
    /// (= scale group) the affine params fold into the query once
    /// (`w = q ⊙ s`, `ζ = q·z`; see quant::packing module docs), then every
    /// token in the page costs one BF16 dot plus two packed-code dots.
    ///
    /// `qperm` is the (rotated) query permuted into tier order —
    /// `qperm[j] = q[idx[j]]` — which makes the assembly channel-permutation
    /// aware without any scatter. `w4`/`w2` are caller scratch of at least
    /// `n4`/`n2` elements.
    pub fn scores_into(
        &self,
        qperm: &[f32],
        qlen: usize,
        scale: f32,
        w4: &mut [f32],
        w2: &mut [f32],
        out: &mut [f32],
    ) {
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let g = self.group;
        debug_assert!(qlen <= self.pages.len() * g);
        debug_assert_eq!(qperm.len(), self.d);
        let q16 = &qperm[..n16];
        let q4 = &qperm[n16..n16 + n4];
        let q2 = &qperm[n16 + n4..n16 + n4 + n2];
        let w4 = &mut w4[..n4];
        let w2 = &mut w2[..n2];
        let mut tok = 0;
        while tok < qlen {
            let grp = tok / g;
            let pv = self.layout.view(self.pages[grp].page());
            let mut zdot = 0.0f32;
            for j in 0..n4 {
                w4[j] = q4[j] * pv.k4s[j];
                zdot += q4[j] * pv.k4z[j];
            }
            for j in 0..n2 {
                w2[j] = q2[j] * pv.k2s[j];
                zdot += q2[j] * pv.k2z[j];
            }
            let end = ((grp + 1) * g).min(qlen);
            for t in tok..end {
                let ti = t - grp * g;
                let mut acc = zdot;
                let row16 = &pv.k16[ti * n16..(ti + 1) * n16];
                for j in 0..n16 {
                    acc += q16[j] * row16[j];
                }
                if n4 > 0 {
                    acc += packing::dot_packed_u4(&pv.k4p[ti * n4 / 2..(ti + 1) * n4 / 2], w4);
                }
                if n2 > 0 {
                    acc += packing::dot_packed_u2(&pv.k2p[ti * n2 / 4..(ti + 1) * n2 / 4], w2);
                }
                out[t] = acc * scale;
            }
            tok = end;
        }
    }

    /// Fused value-side attention accumulate: `out[ch] += Σ_t probs[t] *
    /// dequant(v_{t,ch})` streamed page by page from the packed (or BF16)
    /// value buffers — the other half of the zero-dequant decode path.
    pub fn values_accumulate_into(&self, probs: &[f32], out: &mut [f32]) {
        let d = self.d;
        let g = self.group;
        let qlen = probs.len();
        debug_assert!(qlen <= self.pages.len() * g);
        debug_assert_eq!(out.len(), d);
        let gv = self.vgroup();
        let ng = d / gv;
        let mut tok = 0;
        while tok < qlen {
            let grp = tok / g;
            let pv = self.layout.view(self.pages[grp].page());
            let end = ((grp + 1) * g).min(qlen);
            for t in tok..end {
                let ti = t - grp * g;
                let p = probs[t];
                if self.spec.v_bits == 16 {
                    let row = &pv.vfull[ti * d..(ti + 1) * d];
                    for j in 0..d {
                        out[j] += p * row[j];
                    }
                } else {
                    let s = &pv.vs[ti * ng..(ti + 1) * ng];
                    let z = &pv.vz[ti * ng..(ti + 1) * ng];
                    if self.spec.v_bits == 4 {
                        crate::quant::asym::accumulate_row_u4(
                            &pv.vp[ti * d / 2..(ti + 1) * d / 2],
                            p,
                            s,
                            z,
                            gv,
                            out,
                        );
                    } else {
                        crate::quant::asym::accumulate_row_u2(
                            &pv.vp[ti * d / 4..(ti + 1) * d / 4],
                            p,
                            s,
                            z,
                            gv,
                            out,
                        );
                    }
                }
            }
            tok = end;
        }
    }

    /// Stream an f32 tier field's pages into `dst` front-to-back — the
    /// engine's batch-lane gather iterates the page table through this
    /// (`dst` beyond the leased pages is left as the caller zeroed it).
    pub fn copy_field_f32(&self, field: PageField, dst: &mut [f32]) {
        let lay = self.layout;
        let r = match field {
            PageField::K16 => lay.k16r(),
            PageField::K4s => lay.k4sr(),
            PageField::K4z => lay.k4zr(),
            PageField::K2s => lay.k2sr(),
            PageField::K2z => lay.k2zr(),
            PageField::Vs => lay.vsr(),
            PageField::Vz => lay.vzr(),
            PageField::Vfull => lay.vfullr(),
            _ => unreachable!("byte field routed to copy_field_f32"),
        };
        let n = r.len();
        for (gi, lease) in self.pages.iter().enumerate() {
            dst[gi * n..(gi + 1) * n].copy_from_slice(&lease.page().f[r.clone()]);
        }
    }

    /// Byte-arena counterpart of [`HeadState::copy_field_f32`].
    pub fn copy_field_u8(&self, field: PageField, dst: &mut [u8]) {
        let lay = self.layout;
        let r = match field {
            PageField::K4p => lay.k4pr(),
            PageField::K2p => lay.k2pr(),
            PageField::Vp => lay.vpr(),
            _ => unreachable!("f32 field routed to copy_field_u8"),
        };
        let n = r.len();
        for (gi, lease) in self.pages.iter().enumerate() {
            dst[gi * n..(gi + 1) * n].copy_from_slice(&lease.page().b[r.clone()]);
        }
    }

    /// Materialize the contiguous (pre-pool) layout for the leased region —
    /// the test oracle for paged↔contiguous bit-identity.
    pub fn contiguous(&self) -> ContiguousHead {
        let np = self.pages.len();
        let lay = self.layout;
        let mut c = ContiguousHead {
            k16: vec![0.0; np * lay.k16r().len()],
            k4p: vec![0; np * lay.k4pr().len()],
            k4s: vec![0.0; np * lay.k4sr().len()],
            k4z: vec![0.0; np * lay.k4zr().len()],
            k2p: vec![0; np * lay.k2pr().len()],
            k2s: vec![0.0; np * lay.k2sr().len()],
            k2z: vec![0.0; np * lay.k2zr().len()],
            vp: vec![0; np * lay.vpr().len()],
            vs: vec![0.0; np * lay.vsr().len()],
            vz: vec![0.0; np * lay.vzr().len()],
            vfull: vec![0.0; np * lay.vfullr().len()],
        };
        self.copy_field_f32(PageField::K16, &mut c.k16);
        self.copy_field_u8(PageField::K4p, &mut c.k4p);
        self.copy_field_f32(PageField::K4s, &mut c.k4s);
        self.copy_field_f32(PageField::K4z, &mut c.k4z);
        self.copy_field_u8(PageField::K2p, &mut c.k2p);
        self.copy_field_f32(PageField::K2s, &mut c.k2s);
        self.copy_field_f32(PageField::K2z, &mut c.k2z);
        self.copy_field_u8(PageField::Vp, &mut c.vp);
        self.copy_field_f32(PageField::Vs, &mut c.vs);
        self.copy_field_f32(PageField::Vz, &mut c.vz);
        self.copy_field_f32(PageField::Vfull, &mut c.vfull);
        c
    }

    /// Exact storage bytes for `qlen` quantized tokens + the residual
    /// (invariant #7; BF16 tier & residual at 2 B/elem, scales f32).
    pub fn bytes_used(&self, qlen: usize) -> usize {
        let g = self.group;
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let gq = qlen / g;
        // deployment layout: BF16 outlier tier, BF16 scales/zeros (the CPU
        // host buffers are f32, but the byte model follows the paper's GPU
        // storage — DESIGN.md §2).
        let key = 2 * qlen * n16
            + qlen * n4 / 2
            + qlen * n2 / 4
            + 2 * (gq * n4 * 2 + gq * n2 * 2)
            + 4 * self.d; // idx
        let val = if self.spec.v_bits == 16 {
            2 * qlen * self.d
        } else {
            qlen * self.d * self.spec.v_bits / 8 + 2 * 2 * qlen * self.d / self.vgroup()
        };
        key + val + self.res.bytes()
    }
}

/// Full per-request cache across layers and kv-heads.
pub struct RequestCache {
    pub qlen: usize,
    pub pos: usize,
    /// heads[layer][kv_head]
    pub heads: Vec<Vec<HeadState>>,
    pub method: Method,
    pub rot: Vec<f32>,
    /// Runtime residual-length knob R (≤ CacheConfig::residual, multiple of G).
    pub r_limit: usize,
    /// What happens when the quantized window is full (extension: sink +
    /// sliding-window eviction — kvcache::eviction).
    pub policy: crate::kvcache::eviction::CachePolicy,
    /// Total tokens dropped by sliding-window eviction (ext1 metric).
    pub evicted_tokens: usize,
    /// Flushes deferred because the shared pool had no free pages — the
    /// tokens kept riding in the residual instead (`append` docs).
    pub flush_deferrals: u64,
    /// One-shot hold set by the scheduler's parking pass: the next append
    /// defers its due flush even if a pool-wide `can_lease` would pass,
    /// because the free pages are reserved for other slots this tick
    /// (without this, a slot later in decode order could steal pages the
    /// scheduler promised to a covered slot). Cleared by the append.
    pub flush_hold: bool,
    /// Tokens at the head of the quantized window whose pages are shared
    /// (refcounted prefix pages adopted from — or registered into — a
    /// `RadixTree`). Shared pages stay a contiguous window prefix even
    /// under sink-preserving eviction (the evicted interior splices out and
    /// the survivors compact), so one scalar tracks the seam; eviction
    /// accounting treats these pages as freeing nothing to the pool (other
    /// holders may keep them alive).
    pub shared_prefix_tokens: usize,
    pool: KvPool,
    mc_n_kv: usize,
    d: usize,
    group: usize,
    capacity: usize,
    /// Stable fault-draw identity of the owning request (the request id in
    /// serving, set by the engine at cache creation; 0 for standalone
    /// caches, which never have an injector installed). Every chaos draw
    /// belonging to this request — lease denials, decode-step faults,
    /// prefill-chunk faults — keys off this plus a per-site ordinal owned
    /// here, so the fault schedule is a pure function of request behavior,
    /// not thread schedule (see `util::faults`).
    fault_key: u64,
    /// Per-request decode-step draw ordinal (one per attempted step).
    decode_fault_seq: u64,
    /// Per-request prefill-chunk draw ordinal (one per attempted advance).
    prefill_fault_seq: u64,
}

impl RequestCache {
    /// Cache backed by a private unbounded pool — standalone use (the
    /// reference driver, unit tests, offline analyses). Serving goes
    /// through [`RequestCache::new_in`] with the server's shared pool.
    pub fn new(
        mc: &ModelConfig,
        cc: &CacheConfig,
        specs: &[TierSpec],
        method: Method,
        r_limit: usize,
    ) -> Self {
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, None);
        Self::new_in(&pool, mc, cc, specs, method, r_limit)
    }

    /// Cache leasing its pages from `pool` (the serving configuration: one
    /// bounded pool shared by every live request).
    pub fn new_in(
        pool: &KvPool,
        mc: &ModelConfig,
        cc: &CacheConfig,
        specs: &[TierSpec],
        method: Method,
        r_limit: usize,
    ) -> Self {
        assert_eq!(specs.len(), mc.n_layers);
        assert!(r_limit > 0 && r_limit <= cc.residual && r_limit % cc.group == 0);
        let heads = specs
            .iter()
            .map(|&s| {
                (0..mc.n_kv_heads)
                    .map(|_| HeadState::new(s, mc.d_head, cc, pool))
                    .collect()
            })
            .collect();
        let rot = method.rotation(mc.d_head);
        RequestCache {
            qlen: 0,
            pos: 0,
            heads,
            method,
            rot,
            r_limit,
            policy: crate::kvcache::eviction::CachePolicy::Stop,
            evicted_tokens: 0,
            flush_deferrals: 0,
            flush_hold: false,
            shared_prefix_tokens: 0,
            pool: pool.clone(),
            mc_n_kv: mc.n_kv_heads,
            d: mc.d_head,
            group: cc.group,
            capacity: cc.capacity,
            fault_key: 0,
            decode_fault_seq: 0,
            prefill_fault_seq: 0,
        }
    }

    /// The pool this cache leases from.
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    /// Install the owning request's fault-draw identity (serving sets this
    /// to the request id at cache creation) and derive each head's lease
    /// draw context from it — distinct per (layer, kv-head) so co-resident
    /// heads' denial schedules decorrelate.
    pub fn set_fault_key(&mut self, key: u64) {
        self.fault_key = key;
        let n_kv = self.mc_n_kv as u64;
        for (l, row) in self.heads.iter_mut().enumerate() {
            for (h, head) in row.iter_mut().enumerate() {
                head.fault_ctx = crate::util::faults::draw_key(key, l as u64 * n_kv + h as u64);
            }
        }
    }

    pub fn fault_key(&self) -> u64 {
        self.fault_key
    }

    /// Next decode-step fault-draw key (advances this request's ordinal) —
    /// the engine consults `FaultSite::DecodeStep` with it once per
    /// attempted step, on the coordinator, before dispatch.
    pub fn next_decode_fault_key(&mut self) -> u64 {
        let k = crate::util::faults::draw_key(self.fault_key, self.decode_fault_seq);
        self.decode_fault_seq += 1;
        k
    }

    /// Next prefill-chunk fault-draw key (advances this request's ordinal).
    pub fn next_prefill_fault_key(&mut self) -> u64 {
        let k = crate::util::faults::draw_key(self.fault_key, self.prefill_fault_seq);
        self.prefill_fault_seq += 1;
        k
    }

    /// Pages currently leased across all layers/heads (shared pages count
    /// once per holder here; the POOL counts each shared page once total).
    pub fn leased_pages(&self) -> usize {
        self.heads.iter().flatten().map(|h| h.pages_leased()).sum()
    }

    /// Shared prefix pages referenced across all layers/heads.
    pub fn shared_pages(&self) -> usize {
        self.heads.iter().flatten().map(|h| h.shared_pages()).sum()
    }

    /// Private (exclusively leased) pages across all layers/heads — what
    /// this request ALONE returns to the pool at retirement, and therefore
    /// the right size for preemption-victim selection.
    pub fn private_pages(&self) -> usize {
        self.leased_pages() - self.shared_pages()
    }

    /// Append the pool identity of every SHARED page this cache references
    /// (one entry per holder — co-held pages repeat across callers, and
    /// the prefix tree contributes its own references; audits dedup by
    /// id). Together with [`RequestCache::private_pages`], this reconciles
    /// live holders against the pool's once-per-page `leased` counter in
    /// `Server::check_invariants`.
    pub fn collect_shared_page_ids(&self, out: &mut Vec<usize>) {
        for head in self.heads.iter().flatten() {
            for p in &head.pages {
                if let super::pool::PageRef::Shared(s) = p {
                    out.push(s.page_id());
                }
            }
        }
    }

    /// Pages one quantization flush leases (`r_limit` tokens across every
    /// layer and kv-head).
    pub fn pages_per_flush(&self) -> usize {
        super::pool::pages_for_tokens(self.r_limit, self.group, self.heads.len(), self.mc_n_kv)
    }

    /// NET pages the next append's due flush must lease — 0 when no flush
    /// is due. In the eviction regime (window full under a sliding-window
    /// policy) the eviction runs first and returns its pages to the pool,
    /// so only the shortfall beyond what eviction frees counts (0 when
    /// `evict >= r_limit` per round — the flush is then self-funding). The
    /// scheduler's parking probe: a slot whose due flush cannot be covered
    /// by the pool (and whose residual is nearly full) is parked instead
    /// of decoded; `append` uses the same number, so a dry pool defers
    /// rather than letting `flush()` bail mid-tick.
    pub fn due_flush_pages(&self) -> usize {
        if self.rlen() < self.r_limit {
            return 0;
        }
        if self.qlen + self.r_limit <= self.capacity {
            return self.pages_per_flush();
        }
        match self.policy {
            // window full, no eviction: no flush can happen — nothing due
            crate::kvcache::eviction::CachePolicy::Stop => 0,
            crate::kvcache::eviction::CachePolicy::SlidingWindow { sink, evict } => {
                // mirror evict_for's rounds to predict the freed tokens.
                // Evicted SHARED pages may be kept alive by co-tenants or
                // the prefix tree, so only private evicted tokens count as
                // pool-funding the flush (pessimistic: worst case the flush
                // defers onto the residual, which is always safe).
                let mut q = self.qlen;
                let mut shared = self.shared_prefix_tokens.min(q);
                let mut freed = 0;
                while q + self.r_limit > self.capacity && q >= sink + evict {
                    let overlap = shared.saturating_sub(sink).min(evict);
                    shared -= overlap;
                    freed += evict - overlap;
                    q -= evict;
                }
                super::pool::pages_for_tokens(
                    self.r_limit.saturating_sub(freed),
                    self.group,
                    self.heads.len(),
                    self.mc_n_kv,
                )
            }
        }
    }

    /// Live residual bytes across all heads (deployment convention) — the
    /// off-pool component of this request's occupancy.
    pub fn residual_bytes(&self) -> usize {
        self.heads.iter().flatten().map(|h| h.res.bytes()).sum()
    }

    /// Residual slots still free: a due-but-deferred flush can ride this
    /// many more tokens before the request would die CacheFull.
    pub fn residual_headroom(&self) -> usize {
        self.heads[0][0].res.capacity - self.rlen()
    }

    /// How the prefill of a `t`-token prompt splits into (quantized,
    /// residual) tokens — shared by `load_prefill` and the scheduler's
    /// exact page-count admission.
    pub fn prefill_split(t: usize, r_limit: usize, group: usize, capacity: usize) -> (usize, usize) {
        let mut qt = if t > r_limit { (t - r_limit).div_ceil(group) * group } else { 0 };
        qt = qt.min(capacity).min(t / group * group);
        (qt, t - qt)
    }

    pub fn rlen(&self) -> usize {
        self.heads[0][0].res.len
    }

    /// Total positions this request still has room for.
    pub fn remaining(&self) -> usize {
        (self.capacity - self.qlen) + (self.heads[0][0].res.capacity - self.rlen())
    }

    /// Load prefill K/V (`k[l]`/`v[l]` row-major [Hkv, T, dh]) + the prompt
    /// |Q| statistic, quantizing everything but the most recent tokens.
    /// Leases the quantized groups' pages up front; fails without leasing
    /// anything when the shared pool cannot cover them.
    pub fn load_prefill(
        &mut self,
        k: &[Vec<f32>],
        v: &[Vec<f32>],
        qabs: &[Vec<f32>],
        t: usize,
    ) -> Result<()> {
        // same capacity/occupancy validation as the chunked path — one
        // derivation, two admission flavors
        self.begin_prefill(t)?;
        let (qt, rl) = Self::prefill_split(t, self.r_limit, self.group, self.capacity);
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let d = self.d;
                let kh = &k[l][h * t * d..(h + 1) * t * d];
                let vh = &v[l][h * t * d..(h + 1) * t * d];
                self.heads[l][h]
                    .qstats
                    .update(&qabs[l][h * d..(h + 1) * d], t as f32);
                if qt > 0 {
                    self.quantize_into(l, h, &kh[..qt * d], &vh[..qt * d], qt, 0)?;
                }
                let head = &mut self.heads[l][h];
                head.res.extend(&kh[qt * d..], &vh[qt * d..], rl);
            }
        }
        self.qlen = qt;
        self.pos = t;
        Ok(())
    }

    /// Validate a chunked prefill of `t` tokens before any layer stores:
    /// the residual leftover must fit X_R and the pool must currently
    /// cover the quantized window's pages. Leases nothing — pages are
    /// leased one group at a time as [`RequestCache::store_prefill_layer`]
    /// stores them (a shared pool drying up mid-run surfaces as an error
    /// from the store; dropping the cache returns what was leased).
    pub fn begin_prefill(&self, t: usize) -> Result<()> {
        let res_cap = self.heads[0][0].res.capacity;
        let (qt, rl) = Self::prefill_split(t, self.r_limit, self.group, self.capacity);
        if rl > res_cap {
            bail!("prompt too long: residual leftover {rl} > capacity {res_cap}");
        }
        let need = super::pool::pages_for_tokens(qt, self.group, self.heads.len(), self.mc_n_kv);
        if !self.pool.can_lease(need) {
            self.pool.note_lease_failure();
            bail!("kv pool exhausted: prefill needs {need} pages");
        }
        Ok(())
    }

    /// Validate a seam-resumed chunked prefill of `t` tokens: the cache
    /// must hold exactly the `seam` installed prefix tokens (a partial
    /// [`RequestCache::install_prefix`]), the residual leftover must fit
    /// X_R, and the pool must cover the *tail* window's pages only — the
    /// matched prefix is already paid for by its shared pages. Leases
    /// nothing, like [`RequestCache::begin_prefill`]. `seam == 0` is the
    /// plain fresh-prefill validation.
    pub fn begin_prefill_from(&self, t: usize, seam: usize) -> Result<()> {
        if seam == 0 {
            return self.begin_prefill(t);
        }
        if self.qlen != seam || self.pos != seam || self.rlen() != 0 {
            bail!(
                "seam resume requires an installed prefix of exactly {seam} tokens \
                 (cache holds qlen {} pos {} rlen {})",
                self.qlen,
                self.pos,
                self.rlen()
            );
        }
        let res_cap = self.heads[0][0].res.capacity;
        let (qt, rl) = Self::prefill_split(t, self.r_limit, self.group, self.capacity);
        if seam > qt || seam % self.group.max(1) != 0 {
            bail!("seam {seam} beyond or misaligned with quantized window {qt}");
        }
        if rl > res_cap {
            bail!("prompt too long: residual leftover {rl} > capacity {res_cap}");
        }
        let need =
            super::pool::pages_for_tokens(qt - seam, self.group, self.heads.len(), self.mc_n_kv);
        if !self.pool.can_lease(need) {
            self.pool.note_lease_failure();
            bail!("kv pool exhausted: resumed prefill needs {need} tail pages");
        }
        Ok(())
    }

    /// Chunked-prefill layer sink: quantize layer `l`'s full-precision K/V
    /// — token-major `[t, Hkv*dh]`, exactly as the blocked forward produces
    /// them — straight into pool pages (one lease per quantization group as
    /// each group stores) plus the f32 residual tail, without ever
    /// materializing the `[L]`-layer prefill stash the legacy
    /// `load_prefill` path consumes. Per head the flow is identical to
    /// `load_prefill` (|q| statistics first, then one whole-window
    /// quantization so KVQuant-style global scales span the full window):
    /// given bit-identical K/V/|q| inputs the stored pages are
    /// bit-identical too (tests/blocked_prefill.rs asserts this across
    /// pooled and private caches). `kbuf`/`vbuf` are caller gather scratch
    /// of at least `t * d_head` elements.
    #[allow(clippy::too_many_arguments)]
    pub fn store_prefill_layer(
        &mut self,
        l: usize,
        k: &[f32],
        v: &[f32],
        qabs: &[f32],
        t: usize,
        kbuf: &mut [f32],
        vbuf: &mut [f32],
    ) -> Result<()> {
        self.store_prefill_layer_from(l, k, v, qabs, t, 0, kbuf, vbuf)
    }

    /// Seam-resumed layer sink: like [`RequestCache::store_prefill_layer`]
    /// but stores only rows `[seam, t)` — the matched prefix's pages are
    /// already installed (shared, read-only), so the tail quantizes into
    /// *new* private pages appended after them (`store_key_window` at a
    /// group-aligned offset). Because the frozen plan is installed
    /// (`planned == true`), `quantize_into` skips channel planning and the
    /// tail packs under the producer's permutation with its own
    /// tail-window scale blocks — the frozen-plan approximation. The |Q|
    /// accumulator continues from the adopted state with the tail's
    /// queries only. `k`/`v` are still full token-major `[t, Hkv*dh]`
    /// buffers (the resumed forward reconstructs prefix rows for
    /// attention); `seam == 0` is the plain full store.
    #[allow(clippy::too_many_arguments)]
    pub fn store_prefill_layer_from(
        &mut self,
        l: usize,
        k: &[f32],
        v: &[f32],
        qabs: &[f32],
        t: usize,
        seam: usize,
        kbuf: &mut [f32],
        vbuf: &mut [f32],
    ) -> Result<()> {
        let d = self.d;
        let stride = self.mc_n_kv * d;
        debug_assert_eq!(k.len(), t * stride);
        debug_assert!(seam <= t && seam % self.group.max(1) == 0);
        debug_assert!(kbuf.len() >= (t - seam) * d && vbuf.len() >= (t - seam) * d);
        let (qt, rl) = Self::prefill_split(t, self.r_limit, self.group, self.capacity);
        debug_assert!(seam <= qt, "seam past the quantized window");
        let tail = t - seam;
        let qtail = qt - seam;
        for h in 0..self.mc_n_kv {
            for s in 0..tail {
                let row = (seam + s) * stride + h * d;
                kbuf[s * d..(s + 1) * d].copy_from_slice(&k[row..row + d]);
                vbuf[s * d..(s + 1) * d].copy_from_slice(&v[row..row + d]);
            }
            self.heads[l][h].qstats.update(&qabs[h * d..(h + 1) * d], tail as f32);
            if qtail > 0 {
                self.quantize_into(l, h, &kbuf[..qtail * d], &vbuf[..qtail * d], qtail, seam)?;
            }
            let head = &mut self.heads[l][h];
            head.res.extend(&kbuf[qtail * d..tail * d], &vbuf[qtail * d..tail * d], rl);
        }
        Ok(())
    }

    /// Reconstruct the installed prefix's K/V rows `[0, seam)` for layer
    /// `l`, token-major `[seam, Hkv*dh]` in RAW channel space — what a
    /// seam-resumed chunked prefill feeds its streaming attention. Keys
    /// dequantize from the shared pages in rotated space, so rotating
    /// methods map them back through Rᵀ ([`rotation::unrotate_rows`]);
    /// values are stored unrotated. Lossy by design: the reconstructed
    /// rows carry the producer's quantization error, which is exactly the
    /// frozen-plan approximation `harness::profiling::frozen_plan_error`
    /// measures against its per-method bound.
    pub fn dequant_prefix_into(&self, l: usize, seam: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        let d = self.d;
        let stride = self.mc_n_kv * d;
        debug_assert!(seam <= self.qlen && seam % self.group.max(1) == 0);
        debug_assert!(k_out.len() >= seam * stride && v_out.len() >= seam * stride);
        for h in 0..self.mc_n_kv {
            let head = &self.heads[l][h];
            let mut kd = head.dequant_keys(seam);
            if self.method.rotate {
                rotation::unrotate_rows(&mut kd, seam, d, &self.rot);
            }
            let vd = head.dequant_values(seam);
            for s in 0..seam {
                k_out[s * stride + h * d..s * stride + (h + 1) * d]
                    .copy_from_slice(&kd[s * d..(s + 1) * d]);
                v_out[s * stride + h * d..s * stride + (h + 1) * d]
                    .copy_from_slice(&vd[s * d..(s + 1) * d]);
            }
        }
    }

    /// Seal a chunked prefill: set the window/position cursors once every
    /// layer has stored (`store_prefill_layer` for `0..n_layers`).
    pub fn finish_prefill(&mut self, t: usize) {
        let (qt, _) = Self::prefill_split(t, self.r_limit, self.group, self.capacity);
        self.qlen = qt;
        self.pos = t;
    }

    /// Publish this cache's freshly prefilled prompt into `tree` under the
    /// quantization-identity `seed` (see `pool::prefix_seed` — `prompt` is
    /// the token sequence the chain links derive from; nodes and the tail
    /// retain token copies so every probe verifies them and a hash
    /// collision can never serve the wrong prompt's pages): the quantized
    /// window's pages convert to shared read-only form in place, one radix
    /// node per group, and the tail captures the channel plans, |Q| state,
    /// residual tail, and `last_logits` — enough for a later request with
    /// the same prompt to skip its prefill entirely, and for one with the
    /// same prompt *prefix* to resume from the seam under the frozen plan.
    /// Must be called before any decode appends (the payload must be
    /// exactly the prompt's prefill state); returns false without side
    /// effects on a duplicate key, an evicted window, a prompt that does
    /// not match this cache's state, or a payload the tree's page cap
    /// could never accept — every refusal happens BEFORE the sidecar is
    /// assembled, so it copies nothing. (Collision and plan-conflict
    /// refusals happen inside [`RadixTree::register`], after assembly —
    /// they require the chain walk.)
    pub fn register_prefix(
        &mut self,
        tree: &mut RadixTree,
        seed: u64,
        prompt: &[i32],
        last_logits: &[f32],
    ) -> bool {
        let key = super::pool::prompt_chain_key(seed, prompt, self.group);
        // an evicted window is no longer the pristine prompt prefill (and
        // makes pos != qlen + rlen below) — refuse it BEFORE any assert
        if self.evicted_tokens > 0 || prompt.len() != self.pos || tree.contains(key) {
            return false;
        }
        debug_assert_eq!(
            self.pos,
            self.qlen + self.rlen(),
            "register_prefix requires the pristine prefill state (no appends yet)"
        );
        let groups = self.qlen / self.group;
        let nl = self.heads.len();
        if !tree.would_accept(groups * nl * self.mc_n_kv) {
            return false;
        }
        let planned = groups > 0;
        let mut pages = Vec::with_capacity(nl);
        let mut plans = Vec::with_capacity(if planned { nl } else { 0 });
        let mut qstats = Vec::with_capacity(nl);
        let mut res_k = Vec::with_capacity(nl);
        let mut res_v = Vec::with_capacity(nl);
        for row in self.heads.iter_mut() {
            let mut prow = Vec::with_capacity(self.mc_n_kv);
            let mut plrow = Vec::with_capacity(self.mc_n_kv);
            let mut qrow = Vec::with_capacity(self.mc_n_kv);
            let mut krow = Vec::with_capacity(self.mc_n_kv);
            let mut vrow = Vec::with_capacity(self.mc_n_kv);
            for head in row.iter_mut() {
                prow.push(head.share_prefix(groups));
                if planned {
                    plrow.push(head.idx.clone());
                }
                qrow.push((head.qstats.sum_abs.clone(), head.qstats.count));
                krow.push(head.res.keys().to_vec());
                vrow.push(head.res.values().to_vec());
            }
            pages.push(prow);
            if planned {
                plans.push(plrow);
            }
            qstats.push(qrow);
            res_k.push(krow);
            res_v.push(vrow);
        }
        // the producer's own prefix is shared from here on, whatever the
        // tree decides — eviction accounting must go pessimistic
        self.shared_prefix_tokens = self.qlen;
        let payload = PrefixPayload {
            tokens: prompt.to_vec(),
            qt: self.qlen,
            group: self.group,
            d: self.d,
            layers: nl,
            heads: self.mc_n_kv,
            pages,
            plans,
            qstats,
            res_k,
            res_v,
            last_logits: last_logits.to_vec(),
        };
        tree.register(seed, payload)
    }

    /// Adopt a probe result: reference its shared pages (no lease, no
    /// quantization), restore the channel plans and |Q| statistics that
    /// produced them, copy the bounded residual tail, and set the cursors.
    /// For a **full** match that is the whole prefill, skipped; for a
    /// **partial** match (`t == qt == matched tokens`, empty residual) the
    /// cache is left at the divergence seam — frozen plan installed,
    /// `planned` set — ready for [`RequestCache::begin_prefill_from`]. The
    /// cache must be fresh; the match must come from a probe whose seed
    /// matches this cache's method/geometry (`pool::prefix_seed`
    /// guarantees that in serving).
    pub fn install_prefix(&mut self, m: &PrefixMatch) -> Result<()> {
        if self.pos != 0 || self.qlen != 0 || self.rlen() != 0 {
            bail!("install_prefix requires a fresh cache");
        }
        let nl = self.heads.len();
        if (m.qt > 0
            && (m.pages.len() != nl || m.pages.first().map(Vec::len) != Some(self.mc_n_kv)))
            || m.group != self.group
            || m.d != self.d
        {
            bail!("prefix match geometry mismatch");
        }
        let rl = m.t - m.qt;
        if rl > self.heads[0][0].res.capacity || m.qt > self.capacity {
            bail!("prefix match exceeds this cache's window/residual capacity");
        }
        let planned = m.qt > 0;
        for (l, row) in self.heads.iter_mut().enumerate() {
            for (h, head) in row.iter_mut().enumerate() {
                if planned {
                    head.pages =
                        m.pages[l][h].iter().cloned().map(PageRef::Shared).collect();
                    head.idx = m.plans[l][h].clone();
                    head.planned = true;
                }
                let (sum_abs, count) = &m.qstats[l][h];
                head.qstats.sum_abs.copy_from_slice(sum_abs);
                head.qstats.count = *count;
                if rl > 0 {
                    head.res.extend(&m.res_k[l][h], &m.res_v[l][h], rl);
                }
            }
        }
        self.qlen = m.qt;
        self.pos = m.t;
        self.shared_prefix_tokens = m.qt;
        Ok(())
    }

    /// Append one decoded token's K/V/|Q| (from the decode step outputs);
    /// triggers a lazy quantization flush when the residual has reached
    /// `r_limit`. When the quantized window is full, tokens keep
    /// accumulating in the residual until it genuinely overflows. When a
    /// flush is due but the **shared pool** has no pages (and eviction
    /// would not free any), the flush is deferred the same way — the token
    /// rides in the residual and `flush_deferrals` counts the stall; the
    /// scheduler parks the slot before the residual itself overflows.
    pub fn append(&mut self, knew: &[Vec<f32>], vnew: &[Vec<f32>], qabs: &[Vec<f32>]) -> Result<()> {
        let res_cap = self.heads[0][0].res.capacity;
        let can_flush = self.qlen + self.r_limit <= self.capacity
            || !matches!(self.policy, crate::kvcache::eviction::CachePolicy::Stop);
        if self.rlen() >= self.r_limit && can_flush {
            // net demand on the pool: eviction (window full under a
            // sliding-window policy) frees its pages before the flush
            // leases, so only the shortfall counts — due_flush_pages
            // mirrors exactly that
            let net = self.due_flush_pages();
            let pool_dry = net > 0 && !self.pool.can_lease(net);
            if pool_dry || self.flush_hold {
                if self.rlen() >= res_cap {
                    bail!(
                        "cache exhausted at pos {}: pool has no pages and residual is full",
                        self.pos
                    );
                }
                self.flush_deferrals += 1;
                if pool_dry {
                    self.pool.note_lease_failure();
                }
            } else {
                self.flush()?;
            }
        }
        self.flush_hold = false;
        if self.rlen() >= res_cap {
            bail!("cache exhausted at pos {}", self.pos);
        }
        let d = self.d;
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let head = &mut self.heads[l][h];
                head.qstats.update(&qabs[l][h * d..(h + 1) * d], 1.0);
                head.res.push(&knew[l][h * d..(h + 1) * d], &vnew[l][h * d..(h + 1) * d]);
            }
        }
        self.pos += 1;
        Ok(())
    }

    /// Quantize `r_limit` residual tokens into the window (the App. D.1
    /// KeyQuant event), leasing one page per group per head. Errors without
    /// partial mutation when the pool cannot cover the whole block.
    pub fn flush(&mut self) -> Result<()> {
        let t = self.r_limit;
        if self.qlen + t > self.capacity {
            // extension: sliding-window eviction instead of failing — the
            // evicted blocks' pages return to the pool before we lease
            let n = self.evict_for(self.policy, t);
            self.evicted_tokens += n;
        }
        if self.qlen + t > self.capacity {
            bail!("quantized window full ({} + {t} > {})", self.qlen, self.capacity);
        }
        let need = self.pages_per_flush();
        if !self.pool.can_lease(need) {
            self.pool.note_lease_failure();
            bail!("kv pool exhausted: flush needs {need} pages");
        }
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let (kblk, vblk) = self.heads[l][h].res.drain(t);
                let at = self.qlen;
                self.quantize_into(l, h, &kblk, &vblk, t, at)?;
            }
        }
        self.qlen += t;
        Ok(())
    }

    /// Recompute the channel plan from current I_d (refresh ablation; also
    /// re-quantizes nothing — only affects FUTURE windows, mirroring the
    /// paper's periodic salience update).
    pub fn replan(&mut self) {
        for row in self.heads.iter_mut() {
            for head in row.iter_mut() {
                head.planned = false;
            }
        }
    }

    fn quantize_into(
        &mut self,
        l: usize,
        h: usize,
        k: &[f32],
        v: &[f32],
        t: usize,
        at: usize,
    ) -> Result<()> {
        let d = self.d;
        let g = self.group;
        let opts = self.method.key_opts(g);
        // rotate keys into quantization space
        let mut krot = k.to_vec();
        if self.method.rotate {
            rotation::rotate_rows(&mut krot, t, d, &self.rot);
        }
        let head = &mut self.heads[l][h];
        if !head.planned {
            let imp = head.qstats.importance();
            let order = window::plan_order(self.method.ordering, &imp, &krot, t, d);
            head.idx = order.iter().map(|&x| x as i32).collect();
            head.planned = true;
        }
        let order: Vec<usize> = head.idx.iter().map(|&x| x as usize).collect();
        let kw = window::quantize_key_window(&krot, t, d, head.spec, &order, opts);
        head.store_key_window(&kw, at)?;
        let gv = g.min(d);
        let vw = window::quantize_value_window(v, t, d, head.spec.v_bits, gv);
        head.store_value_window(&vw, at);
        // both stores complete — the pages are immutable from here on
        // (later flushes lease NEW pages), so seal their integrity
        // checksums for live scrubs and snapshot verification
        head.seal_groups(at, t);
        Ok(())
    }

    /// Exact cache bytes across all layers/heads (invariant #7).
    pub fn bytes_used(&self) -> usize {
        self.heads
            .iter()
            .flat_map(|row| row.iter())
            .map(|h| h.bytes_used(self.qlen))
            .sum()
    }

    /// What the same context would cost in 16-bit (the Fig. 5 baseline).
    pub fn bytes_fp16_equiv(&self) -> usize {
        let toks = self.qlen + self.rlen();
        self.heads.len() * self.mc_n_kv * toks * self.d * 2 * 2
    }

    /// Importance snapshot for analyses (Fig. 3).
    pub fn importance(&self, l: usize, h: usize) -> Vec<f32> {
        self.heads[l][h].qstats.importance()
    }

    /// Visit every page this cache references (with its shared flag), in
    /// deterministic (layer, head, group) order — the snapshot's
    /// page-numbering pass and the live scrub both walk holders this way.
    pub fn for_each_page(&self, f: &mut dyn FnMut(&crate::kvcache::pool::Page, bool)) {
        for row in &self.heads {
            for head in row {
                for p in &head.pages {
                    f(p.page(), p.is_shared());
                }
            }
        }
    }

    /// Serialize this cache's mutable state (cursors, policy, fault
    /// ordinals, and per-head plans/|Q| stats/residual rows/page tables).
    /// Geometry and method identity are NOT written here — the server
    /// records the method name and `r_limit` alongside and rebuilds the
    /// scaffold from config, then overlays with
    /// [`RequestCache::read_snap`]. `serial_of` maps a page's pool
    /// identity ([`crate::kvcache::pool::Page::id`]) to its snapshot
    /// serial (the server numbers pages once across all holders).
    pub fn write_snap<W: std::io::Write>(
        &self,
        w: &mut crate::util::snapshot::SnapWriter<W>,
        serial_of: &mut dyn FnMut(usize) -> u32,
    ) -> crate::util::snapshot::SnapResult<()> {
        w.usize(self.qlen)?;
        w.usize(self.pos)?;
        w.usize(self.evicted_tokens)?;
        w.usize(self.shared_prefix_tokens)?;
        w.u64(self.flush_deferrals)?;
        w.bool(self.flush_hold)?;
        match self.policy {
            crate::kvcache::eviction::CachePolicy::Stop => w.u8(0)?,
            crate::kvcache::eviction::CachePolicy::SlidingWindow { sink, evict } => {
                w.u8(1)?;
                w.usize(sink)?;
                w.usize(evict)?;
            }
        }
        w.u64(self.fault_key)?;
        w.u64(self.decode_fault_seq)?;
        w.u64(self.prefill_fault_seq)?;
        for row in &self.heads {
            for head in row {
                w.bool(head.planned)?;
                w.slice_i32(&head.idx)?;
                w.u64(head.lease_seq)?;
                w.slice_f32(&head.qstats.sum_abs)?;
                w.f32(head.qstats.count)?;
                head.res.write_snap(w)?;
                w.usize(head.pages.len())?;
                for p in &head.pages {
                    w.bool(p.is_shared())?;
                    w.u32(serial_of(p.page().id()))?;
                }
            }
        }
        Ok(())
    }

    /// Overlay snapshotted state onto this freshly constructed cache (same
    /// method/geometry as the writer — the server's geometry guard and
    /// method re-resolution guarantee that). Page serials resolve through
    /// the caller: `resolve_private` hands over the exclusive lease on a
    /// reloaded page (each private serial has exactly one owner);
    /// `resolve_shared` returns one reference to a shared page. Either
    /// answering `None` — the payload failed its checksum — poisons the
    /// cache: the record is still consumed (the stream stays aligned) and
    /// `Ok(false)` tells the caller to retire the owning request instead
    /// of aborting the load. Structural damage is a hard `Err`.
    pub fn read_snap<R: std::io::Read>(
        &mut self,
        r: &mut crate::util::snapshot::SnapReader<R>,
        resolve_private: &mut dyn FnMut(u32) -> Option<PageLease>,
        resolve_shared: &mut dyn FnMut(u32) -> Option<SharedLease>,
    ) -> crate::util::snapshot::SnapResult<bool> {
        use crate::util::snapshot::corrupt;
        self.qlen = r.usize("cache qlen")?;
        self.pos = r.usize("cache pos")?;
        self.evicted_tokens = r.usize("cache evicted_tokens")?;
        self.shared_prefix_tokens = r.usize("cache shared_prefix_tokens")?;
        self.flush_deferrals = r.u64("cache flush_deferrals")?;
        self.flush_hold = r.bool("cache flush_hold")?;
        self.policy = match r.u8("cache policy tag")? {
            0 => crate::kvcache::eviction::CachePolicy::Stop,
            1 => {
                let sink = r.usize("cache policy sink")?;
                let evict = r.usize("cache policy evict")?;
                crate::kvcache::eviction::CachePolicy::SlidingWindow { sink, evict }
            }
            t => return Err(corrupt(format!("cache policy tag {t} (want 0 or 1)"))),
        };
        let fault_key = r.u64("cache fault_key")?;
        // re-derive every head's fault_ctx from the key FIRST; the ordinals
        // read below then overwrite the zeroed counters
        self.set_fault_key(fault_key);
        self.decode_fault_seq = r.u64("cache decode_fault_seq")?;
        self.prefill_fault_seq = r.u64("cache prefill_fault_seq")?;
        let mut healthy = true;
        for row in self.heads.iter_mut() {
            for head in row.iter_mut() {
                head.planned = r.bool("head planned")?;
                let idx = r.vec_i32("head plan")?;
                if idx.len() != head.d {
                    return Err(corrupt(format!(
                        "head plan has {} channels (geometry says {})",
                        idx.len(),
                        head.d
                    )));
                }
                head.idx = idx;
                head.lease_seq = r.u64("head lease_seq")?;
                let sum_abs = r.vec_f32("head qstat sums")?;
                if sum_abs.len() != head.qstats.sum_abs.len() {
                    return Err(corrupt(format!(
                        "head qstats have {} channels (geometry says {})",
                        sum_abs.len(),
                        head.qstats.sum_abs.len()
                    )));
                }
                head.qstats.sum_abs = sum_abs;
                head.qstats.count = r.f32("head qstat count")?;
                head.res.read_snap(r)?;
                let n_pages = r.len("head page count")?;
                head.pages.clear();
                for _ in 0..n_pages {
                    let shared = r.bool("head page shared flag")?;
                    let serial = r.u32("head page serial")?;
                    if shared {
                        match resolve_shared(serial) {
                            Some(s) => head.pages.push(PageRef::Shared(s)),
                            None => healthy = false,
                        }
                    } else {
                        match resolve_private(serial) {
                            Some(l) => head.pages.push(PageRef::Private(l)),
                            None => healthy = false,
                        }
                    }
                }
            }
        }
        Ok(healthy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup(method: Method, r_limit: usize) -> (ModelConfig, CacheConfig, RequestCache) {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let cache = RequestCache::new(&mc, &cc, &vec![spec; 2], method, r_limit);
        (mc, cc, cache)
    }

    fn rand_kv(rng: &mut Pcg32, mc: &ModelConfig, t: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let n = mc.n_kv_heads * t * mc.d_head;
        let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let qa = (0..mc.n_layers)
            .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
            .collect();
        (k, v, qa)
    }

    #[test]
    fn request_cache_is_send() {
        // worker-pool jobs carry &mut RequestCache across threads, and the
        // per-head attention split shares &[HeadState] between workers
        fn assert_send<T: Send>() {}
        fn assert_sync<T: Sync>() {}
        assert_send::<RequestCache>();
        assert_send::<HeadState>();
        assert_sync::<RequestCache>();
        assert_sync::<HeadState>();
    }

    #[test]
    fn fault_keys_are_deterministic_per_request() {
        let (_, _, mut a) = setup(Method::mixkvq("mix30"), 128);
        let (_, _, mut b) = setup(Method::mixkvq("mix30"), 128);
        a.set_fault_key(42);
        b.set_fault_key(42);
        for _ in 0..8 {
            assert_eq!(a.next_decode_fault_key(), b.next_decode_fault_key());
            assert_eq!(a.next_prefill_fault_key(), b.next_prefill_fault_key());
        }
        // distinct requests draw from distinct key sequences
        let (_, _, mut c) = setup(Method::mixkvq("mix30"), 128);
        c.set_fault_key(43);
        assert_ne!(a.next_decode_fault_key(), c.next_decode_fault_key());
        // heads get decorrelated lease contexts
        assert_ne!(a.heads[0][0].fault_ctx, a.heads[0][1].fault_ctx);
        assert_ne!(a.heads[0][0].fault_ctx, a.heads[1][0].fault_ctx);
    }

    #[test]
    fn prefill_split_respects_r_limit_and_alignment() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 128);
        let mut rng = Pcg32::seeded(61);
        let t = 300;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen % 32, 0);
        assert_eq!(cache.qlen + cache.rlen(), t);
        assert!(cache.rlen() <= 128);
        assert_eq!(cache.pos, t);
        // t=300, r=128: qt = ceil(172/32)*32 = 192, residual 108
        assert_eq!(cache.qlen, 192);
        assert_eq!(cache.rlen(), 108);
    }

    #[test]
    fn short_prompt_stays_in_residual() {
        let (mc, _, mut cache) = setup(Method::kivi("kv2"), 128);
        let mut rng = Pcg32::seeded(62);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 50);
        cache.load_prefill(&k, &v, &qa, 50).unwrap();
        assert_eq!(cache.qlen, 0);
        assert_eq!(cache.rlen(), 50);
        // a short prompt leases NO pages — the point of the pool refactor
        assert_eq!(cache.leased_pages(), 0);
        // residual keys are bit-exact (invariant #5)
        let d = mc.d_head;
        assert_eq!(cache.heads[0][1].res.keys(), &k[0][1 * 50 * d..1 * 50 * d + 50 * d]);
    }

    #[test]
    fn append_triggers_flush_at_r_limit() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(63);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 20);
        cache.load_prefill(&k, &v, &qa, 20).unwrap();
        assert_eq!(cache.qlen, 0);
        for step in 0..13 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            cache.append(&kn, &vn, &qn).unwrap();
            assert_eq!(cache.pos, 21 + step);
        }
        // residual hit 32 = r_limit after 12 appends; the 13th flushes first
        assert_eq!(cache.qlen, 32);
        assert_eq!(cache.rlen(), 1);
    }

    #[test]
    fn dequant_roundtrip_error_bounded() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(64);
        let t = 64;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen, 32);
        let d = mc.d_head;
        let kq = cache.heads[0][0].dequant_keys(cache.qlen);
        let korig = &k[0][..32 * d];
        let err = kq.iter().zip(korig).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 2.0, "{err}");
        // 2 bf16 channels exact per token
        let vq = cache.heads[0][0].dequant_values(cache.qlen);
        let verr = vq
            .iter()
            .zip(&v[0][..32 * d])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(verr < 2.0, "{verr}");
    }

    #[test]
    fn streaming_accessors_match_dequant_round_trip() {
        // scores_into / values_accumulate_into over the packed pages must
        // agree with dequantize-then-dot for every tier mix.
        let mut rng = Pcg32::seeded(68);
        for (spec, method) in [
            (TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }, Method::mixkvq("mix30")),
            (TierSpec { n16: 0, n4: 32, n2: 0, v_bits: 4 }, Method::kivi("kv4")),
            (TierSpec { n16: 0, n4: 0, n2: 32, v_bits: 2 }, Method::kvquant("kv2")),
            (TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }, Method::bf16()),
        ] {
            let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
            let cc = CacheConfig::default_build();
            let mut cache = RequestCache::new(&mc, &cc, &[spec], method, 32);
            let t = 96;
            let n = mc.n_kv_heads * t * mc.d_head;
            let k: Vec<Vec<f32>> = vec![(0..n).map(|_| rng.normal()).collect()];
            let v: Vec<Vec<f32>> = vec![(0..n).map(|_| rng.normal()).collect()];
            let qa: Vec<Vec<f32>> =
                vec![(0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect()];
            cache.load_prefill(&k, &v, &qa, t).unwrap();
            let q = cache.qlen;
            assert!(q >= 64);
            let d = mc.d_head;
            let head = &cache.heads[0][0];
            // random rotated-space query, permuted into tier order
            let qvec: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let qperm: Vec<f32> = head.idx.iter().map(|&i| qvec[i as usize]).collect();
            let mut w4 = vec![0f32; d];
            let mut w2 = vec![0f32; d];
            let mut got = vec![0f32; q];
            head.scores_into(&qperm, q, 0.25, &mut w4, &mut w2, &mut got);
            let kd = head.dequant_keys(q);
            for tok in 0..q {
                let want: f32 =
                    (0..d).map(|ch| qvec[ch] * kd[tok * d + ch]).sum::<f32>() * 0.25;
                assert!((got[tok] - want).abs() < 1e-4, "spec {spec:?} tok {tok}");
            }
            let probs: Vec<f32> = (0..q).map(|_| rng.f32() / q as f32).collect();
            let mut ov = vec![0f32; d];
            head.values_accumulate_into(&probs, &mut ov);
            let vd = head.dequant_values(q);
            for ch in 0..d {
                let want: f32 = (0..q).map(|tok| probs[tok] * vd[tok * d + ch]).sum();
                assert!((ov[ch] - want).abs() < 1e-4, "spec {spec:?} ch {ch}");
            }
        }
    }

    #[test]
    fn rotation_roundtrip_through_cache() {
        // RotateKV path: dequant(quant(k·H)) ≈ k·H, so scores with rotated q
        // approximate exact scores.
        let (mc, _, mut cache) = setup(Method::rotatekv("kv4"), 32);
        let mut rng = Pcg32::seeded(65);
        let t = 64; // > r_limit so 32 tokens land in the quantized window
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen, 32);
        let d = mc.d_head;
        let kq = cache.heads[0][0].dequant_keys(32); // rotated space
        let mut krot = k[0][..32 * d].to_vec();
        rotation::rotate_rows(&mut krot, 32, d, &cache.rot);
        // setup() uses the mix30 spec: 28 channels sit at 2-bit, so bound by
        // the 2-bit worst case of a rotated gaussian (range/3 / 2)
        let err = kq.iter().zip(&krot).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 1.5, "{err}");
    }

    #[test]
    fn bytes_used_smaller_than_fp16() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix225"), 32);
        let mut rng = Pcg32::seeded(66);
        let t = 512;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        let used = cache.bytes_used();
        let fp16 = cache.bytes_fp16_equiv();
        assert!(
            (used as f64) < 0.45 * fp16 as f64,
            "used={used} fp16={fp16} ratio={}",
            used as f64 / fp16 as f64
        );
    }

    #[test]
    fn flush_overflow_errors() {
        let (mc, _, mut cache) = setup(Method::kivi("kv2"), 128);
        let mut rng = Pcg32::seeded(67);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 512);
        cache.load_prefill(&k, &v, &qa, 512).unwrap();
        // qt = ceil(384/32)*32 = 384, residual starts at 128 (= r_limit)
        assert_eq!(cache.qlen, 384);
        // first append flushes (384+128 <= 512) then pushes; subsequent
        // appends fill the residual until it genuinely overflows.
        let mut err_at = None;
        for i in 0..200 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            if cache.append(&kn, &vn, &qn).is_err() {
                err_at = Some(i);
                break;
            }
        }
        // after flush: qlen=512 (full); residual has 1 + 127 more = 128 slots
        assert_eq!(cache.qlen, 512);
        assert_eq!(err_at, Some(128), "should exhaust exactly at residual cap");
    }

    #[test]
    fn page_accounting_tracks_qlen_and_returns_on_drop() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(69);
        let t = 128;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        // one page per group per (layer, kv-head)
        let groups = cache.qlen / 32;
        assert_eq!(cache.leased_pages(), groups * mc.n_layers * mc.n_kv_heads);
        assert_eq!(cache.pool().leased(), cache.leased_pages());
        assert_eq!(cache.pages_per_flush(), mc.n_layers * mc.n_kv_heads);
        let pool = cache.pool().clone();
        drop(cache);
        assert_eq!(pool.leased(), 0, "retirement must return every page");
    }

    #[test]
    fn chunked_layer_store_is_bit_identical_to_load_prefill() {
        // Same K/V/|q| through the chunked-prefill sink (token-major,
        // layer at a time) and the legacy bulk path must produce the same
        // pages, residual, and cursors — bit for bit.
        let (mc, _, mut legacy) = setup(Method::mixkvq("mix30"), 32);
        let (_, _, mut chunked) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(71);
        let t = 100; // unaligned: 64 quantized + 36 residual
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        legacy.load_prefill(&k, &v, &qa, t).unwrap();
        let d = mc.d_head;
        let stride = mc.n_kv_heads * d;
        let mut kbuf = vec![0f32; t * d];
        let mut vbuf = vec![0f32; t * d];
        chunked.begin_prefill(t).unwrap();
        for l in 0..mc.n_layers {
            // convert the head-major fixture to the token-major layout the
            // blocked forward produces
            let mut kt = vec![0f32; t * stride];
            let mut vt = vec![0f32; t * stride];
            for h in 0..mc.n_kv_heads {
                for s in 0..t {
                    kt[s * stride + h * d..s * stride + (h + 1) * d]
                        .copy_from_slice(&k[l][h * t * d + s * d..h * t * d + (s + 1) * d]);
                    vt[s * stride + h * d..s * stride + (h + 1) * d]
                        .copy_from_slice(&v[l][h * t * d + s * d..h * t * d + (s + 1) * d]);
                }
            }
            chunked
                .store_prefill_layer(l, &kt, &vt, &qa[l], t, &mut kbuf, &mut vbuf)
                .unwrap();
        }
        chunked.finish_prefill(t);
        assert_eq!(chunked.qlen, legacy.qlen);
        assert_eq!(chunked.pos, legacy.pos);
        assert_eq!(chunked.rlen(), legacy.rlen());
        assert_eq!(chunked.leased_pages(), legacy.leased_pages());
        for l in 0..mc.n_layers {
            for h in 0..mc.n_kv_heads {
                let (a, b) = (&chunked.heads[l][h], &legacy.heads[l][h]);
                assert_eq!(a.idx, b.idx, "l={l} h={h}: channel plans differ");
                assert_eq!(a.contiguous(), b.contiguous(), "l={l} h={h}");
                assert_eq!(a.res.keys(), b.res.keys());
                assert_eq!(a.res.values(), b.res.values());
            }
        }
    }

    fn probe_full(
        tree: &mut crate::kvcache::radix::RadixTree,
        seed: u64,
        prompt: &[i32],
        group: usize,
    ) -> crate::kvcache::radix::PrefixMatch {
        match tree.lookup(seed, prompt, group, 0) {
            crate::kvcache::radix::PrefixProbe::Full(m) => m,
            _ => panic!("expected a full prefix hit"),
        }
    }

    #[test]
    fn register_install_roundtrip_and_cow_divergence() {
        use crate::kvcache::pool::KvPool;
        use crate::kvcache::radix::{PrefixPeek, RadixTree};
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let specs = vec![spec; 2];
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
        pool.prewarm(64);
        let mut tree = RadixTree::new(64, pool.page_deploy_bytes());
        let mut rng = Pcg32::seeded(77);
        let t = 160; // 128 quantized (4 groups) + 32 residual at r_limit=32
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        let method = Method::mixkvq("mix30");
        let mut producer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), 32);
        producer.load_prefill(&k, &v, &qa, t).unwrap();
        let prefix_pages = pool.leased();
        let prompt: Vec<i32> = (0..t as i32).collect();
        let logits = vec![1.5, -2.5, 0.25];
        let seed = 42u64;
        assert!(producer.register_prefix(&mut tree, seed, &prompt, &logits));
        assert_eq!(producer.shared_prefix_tokens, producer.qlen);
        assert_eq!(pool.leased(), prefix_pages, "registration must lease nothing");
        assert_eq!(tree.pages_pinned(), prefix_pages);
        assert_eq!(tree.node_count(), 4, "one node per quantized group");
        assert_eq!(tree.peek(seed, &prompt, cc.group, 0), PrefixPeek::Full);
        // duplicate registration refused; so is a wrong-length prompt
        assert!(!producer.register_prefix(&mut tree, seed, &prompt, &logits));
        assert!(!producer.register_prefix(&mut tree, 43, &prompt[..t - 1], &logits));

        // a private cache fed the same prefill is the bit-identity oracle
        let mut oracle = RequestCache::new(&mc, &cc, &specs, method.clone(), 32);
        oracle.load_prefill(&k, &v, &qa, t).unwrap();

        // consumer adopts the prompt: zero new pool pages, zero compute
        let mut consumer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), 32);
        let m = probe_full(&mut tree, seed, &prompt, cc.group);
        consumer.install_prefix(&m).unwrap();
        drop(m);
        assert_eq!(pool.leased(), prefix_pages, "a hit must lease nothing");
        assert_eq!(consumer.qlen, oracle.qlen);
        assert_eq!(consumer.pos, oracle.pos);
        assert_eq!(consumer.rlen(), oracle.rlen());
        assert_eq!(consumer.shared_pages(), consumer.leased_pages());
        assert_eq!(consumer.private_pages(), 0);
        for l in 0..2 {
            for h in 0..mc.n_kv_heads {
                let (a, b) = (&consumer.heads[l][h], &oracle.heads[l][h]);
                assert_eq!(a.idx, b.idx, "l{l}h{h}: plan must transfer");
                assert!(a.planned);
                assert_eq!(a.qstats.sum_abs, b.qstats.sum_abs);
                assert_eq!(a.qstats.count, b.qstats.count);
                assert_eq!(a.contiguous(), b.contiguous(), "l{l}h{h}");
                assert_eq!(a.res.keys(), b.res.keys());
                assert_eq!(a.res.values(), b.res.values());
            }
        }
        // CoW divergence: decode appends flush into NEW private pages after
        // the shared seam, bit-identical to the oracle fed the same tokens
        for _ in 0..33 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            consumer.append(&kn, &vn, &qn).unwrap();
            oracle.append(&kn, &vn, &qn).unwrap();
        }
        assert_eq!(consumer.qlen, oracle.qlen);
        assert!(consumer.private_pages() > 0, "divergence must lease private pages");
        assert_eq!(consumer.shared_prefix_tokens, 128);
        for l in 0..2 {
            for h in 0..mc.n_kv_heads {
                assert_eq!(
                    consumer.heads[l][h].contiguous(),
                    oracle.heads[l][h].contiguous(),
                    "post-divergence l{l}h{h}"
                );
            }
        }
        let tail = consumer.private_pages();
        assert_eq!(pool.leased(), prefix_pages + tail);
        // retirement returns ONLY the private tail; the tree still pins
        // the prefix (and the producer still references it)
        drop(consumer);
        assert_eq!(pool.leased(), prefix_pages);
        drop(producer);
        assert_eq!(pool.leased(), prefix_pages, "tree pin keeps the prefix alive");
        tree.clear();
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn residual_only_prompt_registers_and_installs_without_pages() {
        use crate::kvcache::pool::KvPool;
        use crate::kvcache::radix::RadixTree;
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let specs = vec![spec; 2];
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(16));
        pool.prewarm(16);
        let mut tree = RadixTree::new(16, pool.page_deploy_bytes());
        let mut rng = Pcg32::seeded(78);
        let t = 20; // < r_limit: everything rides the residual, zero pages
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        let mut producer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
        producer.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(producer.leased_pages(), 0);
        let prompt: Vec<i32> = (0..t as i32).collect();
        assert!(producer.register_prefix(&mut tree, 7, &prompt, &[0.5]));
        assert_eq!(tree.node_count(), 0, "no quantized groups, no nodes");
        let mut consumer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, Method::kivi("kv2"), 32);
        let m = probe_full(&mut tree, 7, &prompt, cc.group);
        consumer.install_prefix(&m).unwrap();
        assert_eq!((consumer.qlen, consumer.pos, consumer.rlen()), (0, t, t));
        assert!(!consumer.heads[0][0].planned, "no window, no plan yet");
        assert_eq!(consumer.heads[0][0].res.keys(), producer.heads[0][0].res.keys());
        // the first flush after divergence plans privately, like any cache
        for _ in 0..13 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            consumer.append(&kn, &vn, &qn).unwrap();
        }
        assert_eq!(consumer.qlen, 32);
        assert!(consumer.heads[0][0].planned);
        assert_eq!(consumer.shared_pages(), 0);
    }

    #[test]
    fn install_prefix_rejects_geometry_mismatch_and_used_cache() {
        use crate::kvcache::pool::KvPool;
        use crate::kvcache::radix::RadixTree;
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let specs = vec![spec; 2];
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let mut rng = Pcg32::seeded(79);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 96);
        let mut producer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
        producer.load_prefill(&k, &v, &qa, 96).unwrap();
        let prompt: Vec<i32> = (0..96).collect();
        assert!(producer.register_prefix(&mut tree, 1, &prompt, &[0.0]));
        // a cache that already holds state must refuse an install
        let mut used =
            RequestCache::new_in(&pool, &mc, &cc, &specs, Method::mixkvq("mix30"), 32);
        used.load_prefill(&k, &v, &qa, 96).unwrap();
        let m = probe_full(&mut tree, 1, &prompt, cc.group);
        assert!(used.install_prefix(&m).is_err());
        // a single-layer cache must refuse a two-layer match
        let mc1 = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
        let mut wrong =
            RequestCache::new(&mc1, &cc, &specs[..1].to_vec(), Method::mixkvq("mix30"), 32);
        assert!(wrong.install_prefix(&m).is_err());
    }

    #[test]
    fn partial_install_resumes_prefill_from_seam() {
        use crate::kvcache::pool::KvPool;
        use crate::kvcache::radix::{PrefixProbe, RadixTree};
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let specs = vec![spec; 2];
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(64));
        pool.prewarm(64);
        let mut tree = RadixTree::new(64, pool.page_deploy_bytes());
        let mut rng = Pcg32::seeded(81);
        let t = 160; // producer: qt = 128 (4 groups)
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        let method = Method::mixkvq("mix30");
        let mut producer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), 32);
        producer.load_prefill(&k, &v, &qa, t).unwrap();
        let prompt: Vec<i32> = (0..t as i32).collect();
        let seed = 5u64;
        assert!(producer.register_prefix(&mut tree, seed, &prompt, &[0.0]));
        let prefix_pages = pool.leased();

        // a prompt sharing the first 3 groups then diverging partial-hits at
        // the deepest verified node: M = 96 tokens
        let mut prompt2 = prompt.clone();
        for x in prompt2.iter_mut().skip(96) {
            *x += 1000;
        }
        let (qt_c, _) = RequestCache::prefill_split(t, 32, cc.group, cc.capacity);
        let cap = RadixTree::partial_walk_groups(qt_c, t, cc.group);
        let m = match tree.lookup(seed, &prompt2, cc.group, cap) {
            PrefixProbe::Partial(m) => m,
            other => panic!("expected partial, got {:?}", std::mem::discriminant(&other)),
        };
        assert_eq!((m.t, m.qt), (96, 96));
        let mut consumer =
            RequestCache::new_in(&pool, &mc, &cc, &specs, method.clone(), 32);
        consumer.install_prefix(&m).unwrap();
        drop(m);
        assert_eq!(pool.leased(), prefix_pages, "partial install leases nothing");
        assert_eq!((consumer.qlen, consumer.pos, consumer.rlen()), (96, 96, 0));
        assert!(consumer.heads[0][0].planned, "frozen plan adopted");
        assert_eq!(consumer.shared_prefix_tokens, 96);

        // resume chunked-prefill bookkeeping from the seam and store the tail
        consumer.begin_prefill_from(t, 96).unwrap();
        let d = mc.d_head;
        let mut kbuf = vec![0.0f32; t * d];
        let mut vbuf = vec![0.0f32; t * d];
        let (k2, v2, qa2) = rand_kv(&mut rng, &mc, t);
        for l in 0..mc.n_layers {
            consumer
                .store_prefill_layer_from(l, &k2[l], &v2[l], &qa2[l], t, 96, &mut kbuf, &mut vbuf)
                .unwrap();
        }
        consumer.finish_prefill(t);
        assert_eq!((consumer.qlen, consumer.pos, consumer.rlen()), (128, 160, 32));
        let tail_pages = consumer.private_pages();
        assert!(tail_pages > 0, "tail group must land in private pages");
        assert_eq!(pool.leased(), prefix_pages + tail_pages);
        assert_eq!(consumer.shared_pages() + tail_pages, consumer.leased_pages());

        // the consumer can extend the tree under the adopted plan: same
        // shared nodes, one new leaf chain for the divergent group
        assert!(consumer.register_prefix(&mut tree, seed, &prompt2, &[0.0]));
        assert_eq!(tree.node_count(), 5, "3 shared + 1 old leaf + 1 new leaf");
        assert_eq!(tree.stats().plan_conflicts, 0);
        drop(consumer);
        drop(producer);
        tree.clear();
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn contiguous_snapshot_roundtrips_through_pages() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(70);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 96);
        cache.load_prefill(&k, &v, &qa, 96).unwrap();
        let head = &cache.heads[0][0];
        let c = head.contiguous();
        let (n16, n2) = (head.spec.n16, head.spec.n2);
        assert_eq!(c.k16.len(), cache.qlen * n16);
        assert_eq!(c.k2p.len(), cache.qlen * n2 / 4);
        assert_eq!(c.k2s.len(), (cache.qlen / 32) * n2);
        // the snapshot and the paged dequant agree on what is stored
        let kd = head.dequant_keys(cache.qlen);
        let d = mc.d_head;
        for tok in 0..cache.qlen {
            for j in 0..n16 {
                assert_eq!(kd[tok * d + head.idx[j] as usize], c.k16[tok * n16 + j]);
            }
        }
    }
}
