//! Per-request quantized KV cache — the Fig. 4 storage layout, held in
//! exactly the buffers the decode HLO consumes:
//!
//! * three-tier quantized key window (BF16 / packed u4 / packed u2 columns,
//!   grouped scales/zeros) at capacity C,
//! * per-token quantized value window,
//! * the full-precision residual buffer X_R,
//! * per-head channel permutation `idx` + the running I_d accumulator.
//!
//! The channel plan (which channels land in which tier) is decided at the
//! first quantization event from (prefill I_d) × (window S_d) and reused for
//! later windows: the decode graph takes one `idx` input per head, so the
//! permutation must be stable across a request. I_d keeps accumulating and
//! is re-consulted if the plan is recomputed via `replan()` (used by the
//! refresh ablation).

use anyhow::{bail, Result};

use crate::model::config::{CacheConfig, ModelConfig};
use crate::quant::methods::Method;
use crate::quant::packing;
use crate::quant::rotation;
use crate::quant::salience::QueryStats;
use crate::quant::window::{self, TierSpec};

use super::residual::ResidualBuffer;

/// One (layer, kv-head) cache shard, ABI-shaped at capacity C.
#[derive(Clone)]
pub struct HeadState {
    pub spec: TierSpec,
    pub d: usize,
    pub capacity: usize,
    pub group: usize,
    /// Channel permutation (tier-concatenated); identity until planned.
    pub idx: Vec<i32>,
    pub planned: bool,
    pub k16: Vec<f32>,
    pub k4p: Vec<u8>,
    pub k4s: Vec<f32>,
    pub k4z: Vec<f32>,
    pub k2p: Vec<u8>,
    pub k2s: Vec<f32>,
    pub k2z: Vec<f32>,
    pub vp: Vec<u8>,
    pub vs: Vec<f32>,
    pub vz: Vec<f32>,
    pub vfull: Vec<f32>,
    pub res: ResidualBuffer,
    pub qstats: QueryStats,
}

impl HeadState {
    /// Value-side channel group: values group along d_head, so G clamps to
    /// d (relevant only for the Table 5 G-sweep where G > d_head).
    pub fn vgroup(&self) -> usize {
        self.group.min(self.d)
    }

    fn new(spec: TierSpec, d: usize, cc: &CacheConfig) -> Self {
        let c = cc.capacity;
        let gk = cc.group;          // key grouping (along tokens)
        let gv = cc.group.min(d);   // value grouping (along channels)
        let cg = c / gk;
        // Packed rows are indexed per-token, so tier widths must fill whole
        // bytes — fail loudly instead of silently corrupting the next
        // token's row (packing::packed_len enforces the same invariant).
        debug_assert!(spec.n4 % 2 == 0, "u4 tier width {} must be even", spec.n4);
        debug_assert!(spec.n2 % 4 == 0, "u2 tier width {} must be a multiple of 4", spec.n2);
        debug_assert!(
            spec.v_bits == 16 || d % (8 / spec.v_bits) == 0,
            "value rows of {d} channels at {}-bit do not fill whole bytes",
            spec.v_bits
        );
        HeadState {
            spec,
            d,
            capacity: c,
            group: gk,
            idx: (0..d as i32).collect(),
            planned: false,
            k16: vec![0.0; c * spec.n16],
            k4p: vec![0; packing::packed_len(c * spec.n4, 4)],
            k4s: vec![0.0; cg * spec.n4],
            k4z: vec![0.0; cg * spec.n4],
            k2p: vec![0; packing::packed_len(c * spec.n2, 2)],
            k2s: vec![0.0; cg * spec.n2],
            k2z: vec![0.0; cg * spec.n2],
            vp: if spec.v_bits == 16 {
                Vec::new()
            } else {
                vec![0; packing::packed_len(c * d, spec.v_bits)]
            },
            vs: if spec.v_bits == 16 { Vec::new() } else { vec![0.0; c * d / gv] },
            vz: if spec.v_bits == 16 { Vec::new() } else { vec![0.0; c * d / gv] },
            vfull: if spec.v_bits == 16 { vec![0.0; c * d] } else { Vec::new() },
            res: ResidualBuffer::new(cc.residual, d),
            qstats: QueryStats::new(d),
        }
    }

    /// Write a quantized key window into the ABI buffers at token offset
    /// `at` (must be group-aligned).
    fn store_key_window(&mut self, w: &window::KeyWindow, at: usize) {
        debug_assert_eq!(at % self.group, 0);
        let t = w.t;
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        self.k16[at * n16..(at + t) * n16].copy_from_slice(&w.k16);
        if n4 > 0 {
            self.k4p[at * n4 / 2..(at + t) * n4 / 2].copy_from_slice(&w.k4p);
            let g0 = at / self.group;
            let gn = t / self.group;
            self.k4s[g0 * n4..(g0 + gn) * n4].copy_from_slice(&w.k4s);
            self.k4z[g0 * n4..(g0 + gn) * n4].copy_from_slice(&w.k4z);
        }
        if n2 > 0 {
            self.k2p[at * n2 / 4..(at + t) * n2 / 4].copy_from_slice(&w.k2p);
            let g0 = at / self.group;
            let gn = t / self.group;
            self.k2s[g0 * n2..(g0 + gn) * n2].copy_from_slice(&w.k2s);
            self.k2z[g0 * n2..(g0 + gn) * n2].copy_from_slice(&w.k2z);
        }
    }

    fn store_value_window(&mut self, w: &window::ValueWindow, at: usize) {
        let (t, d, g) = (w.t, self.d, self.vgroup());
        if self.spec.v_bits == 16 {
            self.vfull[at * d..(at + t) * d].copy_from_slice(&w.vfull);
        } else {
            let b = self.spec.v_bits;
            self.vp[at * d * b / 8..(at + t) * d * b / 8].copy_from_slice(&w.vp);
            self.vs[at * d / g..(at + t) * d / g].copy_from_slice(&w.vs);
            self.vz[at * d / g..(at + t) * d / g].copy_from_slice(&w.vz);
        }
    }

    /// Dequantize the first `qlen` key rows back to f32 in ORIGINAL channel
    /// order (rotated space) — the reference-path view.
    pub fn dequant_keys(&self, qlen: usize) -> Vec<f32> {
        let (d, g) = (self.d, self.group);
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let mut out = vec![0f32; qlen * d];
        let mut row4 = Vec::with_capacity(n4);
        let mut row2 = Vec::with_capacity(n2);
        for t in 0..qlen {
            let grp = t / g;
            for j in 0..n16 {
                out[t * d + self.idx[j] as usize] = self.k16[t * n16 + j];
            }
            row4.clear();
            packing::unpack_u4(&self.k4p[t * n4 / 2..(t + 1) * n4 / 2], &mut row4);
            for j in 0..n4 {
                let s = self.k4s[grp * n4 + j];
                let z = self.k4z[grp * n4 + j];
                out[t * d + self.idx[n16 + j] as usize] = row4[j] as f32 * s + z;
            }
            row2.clear();
            packing::unpack_u2(&self.k2p[t * n2 / 4..(t + 1) * n2 / 4], &mut row2);
            for j in 0..n2 {
                let s = self.k2s[grp * n2 + j];
                let z = self.k2z[grp * n2 + j];
                out[t * d + self.idx[n16 + n4 + j] as usize] = row2[j] as f32 * s + z;
            }
        }
        out
    }

    /// Dequantize the first `qlen` value rows.
    pub fn dequant_values(&self, qlen: usize) -> Vec<f32> {
        let (d, g) = (self.d, self.vgroup());
        if self.spec.v_bits == 16 {
            return self.vfull[..qlen * d].to_vec();
        }
        let b = self.spec.v_bits;
        let ng = d / g;
        let mut out = vec![0f32; qlen * d];
        let mut row = Vec::with_capacity(d);
        for t in 0..qlen {
            row.clear();
            if b == 4 {
                packing::unpack_u4(&self.vp[t * d / 2..(t + 1) * d / 2], &mut row);
            } else {
                packing::unpack_u2(&self.vp[t * d / 4..(t + 1) * d / 4], &mut row);
            }
            for ch in 0..d {
                let s = self.vs[t * ng + ch / g];
                let z = self.vz[t * ng + ch / g];
                out[t * d + ch] = row[ch] as f32 * s + z;
            }
        }
        out
    }

    /// Fused attention scores over the packed quantized window:
    /// `out[t] = scale * q·dequant(k_t)` streamed **directly from the packed
    /// tier buffers** — no f32 window is materialized. Per scale-group the
    /// affine params fold into the query once (`w = q ⊙ s`, `ζ = q·z`; see
    /// quant::packing module docs), then every token in the group costs one
    /// BF16 dot plus two packed-code dots.
    ///
    /// `qperm` is the (rotated) query permuted into tier order —
    /// `qperm[j] = q[idx[j]]` — which makes the assembly channel-permutation
    /// aware without any scatter. `w4`/`w2` are caller scratch of at least
    /// `n4`/`n2` elements.
    pub fn scores_into(
        &self,
        qperm: &[f32],
        qlen: usize,
        scale: f32,
        w4: &mut [f32],
        w2: &mut [f32],
        out: &mut [f32],
    ) {
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let g = self.group;
        debug_assert!(qlen <= self.capacity);
        debug_assert_eq!(qperm.len(), self.d);
        let q16 = &qperm[..n16];
        let q4 = &qperm[n16..n16 + n4];
        let q2 = &qperm[n16 + n4..n16 + n4 + n2];
        let w4 = &mut w4[..n4];
        let w2 = &mut w2[..n2];
        let mut tok = 0;
        while tok < qlen {
            let grp = tok / g;
            let mut zdot = 0.0f32;
            let s4 = &self.k4s[grp * n4..(grp + 1) * n4];
            let z4 = &self.k4z[grp * n4..(grp + 1) * n4];
            for j in 0..n4 {
                w4[j] = q4[j] * s4[j];
                zdot += q4[j] * z4[j];
            }
            let s2 = &self.k2s[grp * n2..(grp + 1) * n2];
            let z2 = &self.k2z[grp * n2..(grp + 1) * n2];
            for j in 0..n2 {
                w2[j] = q2[j] * s2[j];
                zdot += q2[j] * z2[j];
            }
            let end = ((grp + 1) * g).min(qlen);
            for t in tok..end {
                let mut acc = zdot;
                let row16 = &self.k16[t * n16..(t + 1) * n16];
                for j in 0..n16 {
                    acc += q16[j] * row16[j];
                }
                if n4 > 0 {
                    acc += packing::dot_packed_u4(&self.k4p[t * n4 / 2..(t + 1) * n4 / 2], w4);
                }
                if n2 > 0 {
                    acc += packing::dot_packed_u2(&self.k2p[t * n2 / 4..(t + 1) * n2 / 4], w2);
                }
                out[t] = acc * scale;
            }
            tok = end;
        }
    }

    /// Fused value-side attention accumulate: `out[ch] += Σ_t probs[t] *
    /// dequant(v_{t,ch})` streamed directly from the packed (or BF16) value
    /// buffers — the other half of the zero-dequant decode path.
    pub fn values_accumulate_into(&self, probs: &[f32], out: &mut [f32]) {
        let d = self.d;
        let qlen = probs.len();
        debug_assert!(qlen <= self.capacity);
        debug_assert_eq!(out.len(), d);
        if self.spec.v_bits == 16 {
            for (t, &p) in probs.iter().enumerate() {
                let row = &self.vfull[t * d..(t + 1) * d];
                for j in 0..d {
                    out[j] += p * row[j];
                }
            }
            return;
        }
        let g = self.vgroup();
        let ng = d / g;
        for (t, &p) in probs.iter().enumerate() {
            let s = &self.vs[t * ng..(t + 1) * ng];
            let z = &self.vz[t * ng..(t + 1) * ng];
            if self.spec.v_bits == 4 {
                crate::quant::asym::accumulate_row_u4(
                    &self.vp[t * d / 2..(t + 1) * d / 2], p, s, z, g, out,
                );
            } else {
                crate::quant::asym::accumulate_row_u2(
                    &self.vp[t * d / 4..(t + 1) * d / 4], p, s, z, g, out,
                );
            }
        }
    }

    /// Exact storage bytes for `qlen` quantized tokens + the residual
    /// (invariant #7; BF16 tier & residual at 2 B/elem, scales f32).
    pub fn bytes_used(&self, qlen: usize) -> usize {
        let g = self.group;
        let (n16, n4, n2) = (self.spec.n16, self.spec.n4, self.spec.n2);
        let gq = qlen / g;
        // deployment layout: BF16 outlier tier, BF16 scales/zeros (the CPU
        // host buffers are f32, but the byte model follows the paper's GPU
        // storage — DESIGN.md §2).
        let key = 2 * qlen * n16
            + qlen * n4 / 2
            + qlen * n2 / 4
            + 2 * (gq * n4 * 2 + gq * n2 * 2)
            + 4 * self.d; // idx
        let val = if self.spec.v_bits == 16 {
            2 * qlen * self.d
        } else {
            qlen * self.d * self.spec.v_bits / 8 + 2 * 2 * qlen * self.d / self.vgroup()
        };
        key + val + self.res.bytes()
    }
}

/// Full per-request cache across layers and kv-heads.
pub struct RequestCache {
    pub qlen: usize,
    pub pos: usize,
    /// heads[layer][kv_head]
    pub heads: Vec<Vec<HeadState>>,
    pub method: Method,
    pub rot: Vec<f32>,
    /// Runtime residual-length knob R (≤ CacheConfig::residual, multiple of G).
    pub r_limit: usize,
    /// What happens when the quantized window is full (extension: sink +
    /// sliding-window eviction — kvcache::eviction).
    pub policy: crate::kvcache::eviction::CachePolicy,
    /// Total tokens dropped by sliding-window eviction (ext1 metric).
    pub evicted_tokens: usize,
    mc_n_kv: usize,
    d: usize,
    group: usize,
    capacity: usize,
}

impl RequestCache {
    pub fn new(
        mc: &ModelConfig,
        cc: &CacheConfig,
        specs: &[TierSpec],
        method: Method,
        r_limit: usize,
    ) -> Self {
        assert_eq!(specs.len(), mc.n_layers);
        assert!(r_limit > 0 && r_limit <= cc.residual && r_limit % cc.group == 0);
        let heads = specs
            .iter()
            .map(|&s| (0..mc.n_kv_heads).map(|_| HeadState::new(s, mc.d_head, cc)).collect())
            .collect();
        let rot = method.rotation(mc.d_head);
        RequestCache {
            qlen: 0,
            pos: 0,
            heads,
            method,
            rot,
            r_limit,
            policy: crate::kvcache::eviction::CachePolicy::Stop,
            evicted_tokens: 0,
            mc_n_kv: mc.n_kv_heads,
            d: mc.d_head,
            group: cc.group,
            capacity: cc.capacity,
        }
    }

    pub fn rlen(&self) -> usize {
        self.heads[0][0].res.len
    }

    /// Total positions this request still has room for.
    pub fn remaining(&self) -> usize {
        (self.capacity - self.qlen) + (self.heads[0][0].res.capacity - self.rlen())
    }

    /// Load prefill K/V (`k[l]`/`v[l]` row-major [Hkv, T, dh]) + the prompt
    /// |Q| statistic, quantizing everything but the most recent tokens.
    pub fn load_prefill(
        &mut self,
        k: &[Vec<f32>],
        v: &[Vec<f32>],
        qabs: &[Vec<f32>],
        t: usize,
    ) -> Result<()> {
        let res_cap = self.heads[0][0].res.capacity;
        let mut qt = if t > self.r_limit {
            ((t - self.r_limit + self.group - 1) / self.group) * self.group
        } else {
            0
        };
        qt = qt.min(self.capacity).min(t / self.group * self.group);
        let rl = t - qt;
        if rl > res_cap {
            bail!("prompt too long: residual leftover {rl} > capacity {res_cap}");
        }
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let d = self.d;
                let kh = &k[l][h * t * d..(h + 1) * t * d];
                let vh = &v[l][h * t * d..(h + 1) * t * d];
                self.heads[l][h]
                    .qstats
                    .update(&qabs[l][h * d..(h + 1) * d], t as f32);
                if qt > 0 {
                    self.quantize_into(l, h, &kh[..qt * d], &vh[..qt * d], qt, 0);
                }
                let head = &mut self.heads[l][h];
                head.res.extend(&kh[qt * d..], &vh[qt * d..], rl);
            }
        }
        self.qlen = qt;
        self.pos = t;
        Ok(())
    }

    /// Append one decoded token's K/V/|Q| (from the decode step outputs);
    /// triggers a lazy quantization flush when the residual has reached
    /// `r_limit`. When the quantized window is full, tokens keep
    /// accumulating in the residual until it genuinely overflows.
    pub fn append(&mut self, knew: &[Vec<f32>], vnew: &[Vec<f32>], qabs: &[Vec<f32>]) -> Result<()> {
        let can_flush = self.qlen + self.r_limit <= self.capacity
            || !matches!(self.policy, crate::kvcache::eviction::CachePolicy::Stop);
        if self.rlen() >= self.r_limit && can_flush {
            self.flush()?;
        }
        if self.rlen() >= self.heads[0][0].res.capacity {
            bail!("cache exhausted at pos {}", self.pos);
        }
        let d = self.d;
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let head = &mut self.heads[l][h];
                head.qstats.update(&qabs[l][h * d..(h + 1) * d], 1.0);
                head.res.push(&knew[l][h * d..(h + 1) * d], &vnew[l][h * d..(h + 1) * d]);
            }
        }
        self.pos += 1;
        Ok(())
    }

    /// Quantize `r_limit` residual tokens into the window (the App. D.1
    /// KeyQuant event).
    pub fn flush(&mut self) -> Result<()> {
        let t = self.r_limit;
        if self.qlen + t > self.capacity {
            // extension: sliding-window eviction instead of failing
            let n = self.evict_for(self.policy, t);
            self.evicted_tokens += n;
        }
        if self.qlen + t > self.capacity {
            bail!("quantized window full ({} + {t} > {})", self.qlen, self.capacity);
        }
        for l in 0..self.heads.len() {
            for h in 0..self.mc_n_kv {
                let (kblk, vblk) = self.heads[l][h].res.drain(t);
                let at = self.qlen;
                self.quantize_into(l, h, &kblk, &vblk, t, at);
            }
        }
        self.qlen += t;
        Ok(())
    }

    /// Recompute the channel plan from current I_d (refresh ablation; also
    /// re-quantizes nothing — only affects FUTURE windows, mirroring the
    /// paper's periodic salience update).
    pub fn replan(&mut self) {
        for row in self.heads.iter_mut() {
            for head in row.iter_mut() {
                head.planned = false;
            }
        }
    }

    fn quantize_into(&mut self, l: usize, h: usize, k: &[f32], v: &[f32], t: usize, at: usize) {
        let d = self.d;
        let g = self.group;
        let opts = self.method.key_opts(g);
        // rotate keys into quantization space
        let mut krot = k.to_vec();
        if self.method.rotate {
            rotation::rotate_rows(&mut krot, t, d, &self.rot);
        }
        let head = &mut self.heads[l][h];
        if !head.planned {
            let imp = head.qstats.importance();
            let order = window::plan_order(self.method.ordering, &imp, &krot, t, d);
            head.idx = order.iter().map(|&x| x as i32).collect();
            head.planned = true;
        }
        let order: Vec<usize> = head.idx.iter().map(|&x| x as usize).collect();
        let kw = window::quantize_key_window(&krot, t, d, head.spec, &order, opts);
        head.store_key_window(&kw, at);
        let gv = g.min(d);
        let vw = window::quantize_value_window(v, t, d, head.spec.v_bits, gv);
        head.store_value_window(&vw, at);
    }

    /// Exact cache bytes across all layers/heads (invariant #7).
    pub fn bytes_used(&self) -> usize {
        self.heads
            .iter()
            .flat_map(|row| row.iter())
            .map(|h| h.bytes_used(self.qlen))
            .sum()
    }

    /// What the same context would cost in 16-bit (the Fig. 5 baseline).
    pub fn bytes_fp16_equiv(&self) -> usize {
        let toks = self.qlen + self.rlen();
        self.heads.len() * self.mc_n_kv * toks * self.d * 2 * 2
    }

    /// Importance snapshot for analyses (Fig. 3).
    pub fn importance(&self, l: usize, h: usize) -> Vec<f32> {
        self.heads[l][h].qstats.importance()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn setup(method: Method, r_limit: usize) -> (ModelConfig, CacheConfig, RequestCache) {
        let mc = ModelConfig { n_layers: 2, ..ModelConfig::default_build() };
        let cc = CacheConfig::default_build();
        let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
        let cache = RequestCache::new(&mc, &cc, &vec![spec; 2], method, r_limit);
        (mc, cc, cache)
    }

    fn rand_kv(rng: &mut Pcg32, mc: &ModelConfig, t: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let n = mc.n_kv_heads * t * mc.d_head;
        let k = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let v = (0..mc.n_layers).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let qa = (0..mc.n_layers)
            .map(|_| (0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect())
            .collect();
        (k, v, qa)
    }

    #[test]
    fn prefill_split_respects_r_limit_and_alignment() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 128);
        let mut rng = Pcg32::seeded(61);
        let t = 300;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen % 32, 0);
        assert_eq!(cache.qlen + cache.rlen(), t);
        assert!(cache.rlen() <= 128);
        assert_eq!(cache.pos, t);
        // t=300, r=128: qt = ceil(172/32)*32 = 192, residual 108
        assert_eq!(cache.qlen, 192);
        assert_eq!(cache.rlen(), 108);
    }

    #[test]
    fn short_prompt_stays_in_residual() {
        let (mc, _, mut cache) = setup(Method::kivi("kv2"), 128);
        let mut rng = Pcg32::seeded(62);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 50);
        cache.load_prefill(&k, &v, &qa, 50).unwrap();
        assert_eq!(cache.qlen, 0);
        assert_eq!(cache.rlen(), 50);
        // residual keys are bit-exact (invariant #5)
        let d = mc.d_head;
        assert_eq!(cache.heads[0][1].res.keys(), &k[0][1 * 50 * d..1 * 50 * d + 50 * d]);
    }

    #[test]
    fn append_triggers_flush_at_r_limit() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(63);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 20);
        cache.load_prefill(&k, &v, &qa, 20).unwrap();
        assert_eq!(cache.qlen, 0);
        for step in 0..13 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            cache.append(&kn, &vn, &qn).unwrap();
            assert_eq!(cache.pos, 21 + step);
        }
        // residual hit 32 = r_limit after 12 appends; the 13th flushes first
        assert_eq!(cache.qlen, 32);
        assert_eq!(cache.rlen(), 1);
    }

    #[test]
    fn dequant_roundtrip_error_bounded() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix30"), 32);
        let mut rng = Pcg32::seeded(64);
        let t = 64;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen, 32);
        let d = mc.d_head;
        let kq = cache.heads[0][0].dequant_keys(cache.qlen);
        let korig = &k[0][..32 * d];
        let err = kq.iter().zip(korig).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 2.0, "{err}");
        // 2 bf16 channels exact per token
        let vq = cache.heads[0][0].dequant_values(cache.qlen);
        let verr = vq
            .iter()
            .zip(&v[0][..32 * d])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(verr < 2.0, "{verr}");
    }

    #[test]
    fn streaming_accessors_match_dequant_round_trip() {
        // scores_into / values_accumulate_into over the packed buffers must
        // agree with dequantize-then-dot for every tier mix.
        let mut rng = Pcg32::seeded(68);
        for (spec, method) in [
            (TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }, Method::mixkvq("mix30")),
            (TierSpec { n16: 0, n4: 32, n2: 0, v_bits: 4 }, Method::kivi("kv4")),
            (TierSpec { n16: 0, n4: 0, n2: 32, v_bits: 2 }, Method::kvquant("kv2")),
            (TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }, Method::bf16()),
        ] {
            let mc = ModelConfig { n_layers: 1, ..ModelConfig::default_build() };
            let cc = CacheConfig::default_build();
            let mut cache = RequestCache::new(&mc, &cc, &[spec], method, 32);
            let t = 96;
            let n = mc.n_kv_heads * t * mc.d_head;
            let k: Vec<Vec<f32>> = vec![(0..n).map(|_| rng.normal()).collect()];
            let v: Vec<Vec<f32>> = vec![(0..n).map(|_| rng.normal()).collect()];
            let qa: Vec<Vec<f32>> =
                vec![(0..mc.n_kv_heads * mc.d_head).map(|_| rng.f32() + 0.01).collect()];
            cache.load_prefill(&k, &v, &qa, t).unwrap();
            let q = cache.qlen;
            assert!(q >= 64);
            let d = mc.d_head;
            let head = &cache.heads[0][0];
            // random rotated-space query, permuted into tier order
            let qvec: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let qperm: Vec<f32> = head.idx.iter().map(|&i| qvec[i as usize]).collect();
            let mut w4 = vec![0f32; d];
            let mut w2 = vec![0f32; d];
            let mut got = vec![0f32; q];
            head.scores_into(&qperm, q, 0.25, &mut w4, &mut w2, &mut got);
            let kd = head.dequant_keys(q);
            for tok in 0..q {
                let want: f32 =
                    (0..d).map(|ch| qvec[ch] * kd[tok * d + ch]).sum::<f32>() * 0.25;
                assert!((got[tok] - want).abs() < 1e-4, "spec {spec:?} tok {tok}");
            }
            let probs: Vec<f32> = (0..q).map(|_| rng.f32() / q as f32).collect();
            let mut ov = vec![0f32; d];
            head.values_accumulate_into(&probs, &mut ov);
            let vd = head.dequant_values(q);
            for ch in 0..d {
                let want: f32 = (0..q).map(|tok| probs[tok] * vd[tok * d + ch]).sum();
                assert!((ov[ch] - want).abs() < 1e-4, "spec {spec:?} ch {ch}");
            }
        }
    }

    #[test]
    fn rotation_roundtrip_through_cache() {
        // RotateKV path: dequant(quant(k·H)) ≈ k·H, so scores with rotated q
        // approximate exact scores.
        let (mc, _, mut cache) = setup(Method::rotatekv("kv4"), 32);
        let mut rng = Pcg32::seeded(65);
        let t = 64; // > r_limit so 32 tokens land in the quantized window
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        assert_eq!(cache.qlen, 32);
        let d = mc.d_head;
        let kq = cache.heads[0][0].dequant_keys(32); // rotated space
        let mut krot = k[0][..32 * d].to_vec();
        rotation::rotate_rows(&mut krot, 32, d, &cache.rot);
        // setup() uses the mix30 spec: 28 channels sit at 2-bit, so bound by
        // the 2-bit worst case of a rotated gaussian (range/3 / 2)
        let err = kq.iter().zip(&krot).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 1.5, "{err}");
    }

    #[test]
    fn bytes_used_smaller_than_fp16() {
        let (mc, _, mut cache) = setup(Method::mixkvq("mix225"), 32);
        let mut rng = Pcg32::seeded(66);
        let t = 512;
        let (k, v, qa) = rand_kv(&mut rng, &mc, t);
        cache.load_prefill(&k, &v, &qa, t).unwrap();
        let used = cache.bytes_used();
        let fp16 = cache.bytes_fp16_equiv();
        assert!(
            (used as f64) < 0.45 * fp16 as f64,
            "used={used} fp16={fp16} ratio={}",
            used as f64 / fp16 as f64
        );
    }

    #[test]
    fn flush_overflow_errors() {
        let (mc, _, mut cache) = setup(Method::kivi("kv2"), 128);
        let mut rng = Pcg32::seeded(67);
        let (k, v, qa) = rand_kv(&mut rng, &mc, 512);
        cache.load_prefill(&k, &v, &qa, 512).unwrap();
        // qt = ceil(384/32)*32 = 384, residual starts at 128 (= r_limit)
        assert_eq!(cache.qlen, 384);
        // first append flushes (384+128 <= 512) then pushes; subsequent
        // appends fill the residual until it genuinely overflows.
        let mut err_at = None;
        for i in 0..200 {
            let (kn, vn, qn) = rand_kv(&mut rng, &mc, 1);
            if cache.append(&kn, &vn, &qn).is_err() {
                err_at = Some(i);
                break;
            }
        }
        // after flush: qlen=512 (full); residual has 1 + 127 more = 128 slots
        assert_eq!(cache.qlen, 512);
        assert_eq!(err_at, Some(128), "should exhaust exactly at residual cap");
    }
}
