//! Group-aligned radix tree over prompt chunks — the cross-request prefix
//! registry that replaced the flat full-prompt `PrefixIndex` (PR 5).
//!
//! # Shape
//!
//! One interior [`Node`] per **full G-token group** of a registered prompt,
//! keyed by the corresponding intermediate link of the rolling hash chain
//! ([`crate::kvcache::pool::prompt_chain_links`]): node keys for two
//! prompts sharing a group-aligned prefix coincide exactly on the shared
//! groups, so ONE registration serves every prefix length. A node holds
//! its span's [`SharedLease`] pages (one per `(layer, kv-head)`), a copy of
//! its span tokens (the token-verify backstop — a 64-bit link collision is
//! counted and answered as a miss, never served), and an `Rc` of its
//! producer's [`FrozenPlan`] (channel permutations + |Q| statistics). A
//! full-prompt registration additionally anchors a [`TailState`] at its
//! deepest node: the sidecar a consumer needs to skip the prefill entirely
//! (residual rows, last-position logits).
//!
//! # Probe semantics
//!
//! [`RadixTree::lookup`] first checks the full-prompt tail (bit-exact
//! adoption — the PR 5 fast path, `PrefixProbe::Full`); otherwise it walks
//! the chain links group by group, token-verifying each node, and returns
//! the deepest verified match as `PrefixProbe::Partial`. The consumer then
//! runs in **frozen-plan mode**: it adopts the producer's plan + scale
//! state for the matched prefix and resumes chunked prefill from the
//! divergence seam (see `kvcache::cache` for the seam contract). The extra
//! quantization error of frozen-plan adoption is bounded and measured per
//! method by `harness::profiling::frozen_plan_error`; methods whose
//! measured error exceeds the profile-predicted bound keep frozen-plan
//! mode off by default (`Engine::frozen_plan_default`).
//!
//! # Refcounts and shedding
//!
//! A tail pins its anchor node (`Node::tails`), and a node with children
//! or tails is never shed — so every resident chain is intact from depth 1
//! to its deepest consumer. LRU shedding ([`RadixTree::shed_lru`]) only
//! ever removes tails and *leaf* nodes (childless, tailless), eroding cold
//! chains from the deep end; an interior node shared by several suffixes
//! survives until every dependent has been shed. Pages release to the pool
//! the moment their last holder (node or live cache) drops.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::kvcache::pool::{prompt_chain_key, prompt_chain_links, Page, SharedLease};
use crate::util::snapshot::{corrupt, SnapReader, SnapResult, SnapWriter};

/// Hard ceiling on resident tails regardless of the page cap —
/// residual-only prompts pin ZERO pages but still hold a bounded sidecar
/// (prompt copy, residual snapshot, logits), so a page cap alone would let
/// a stream of distinct short prompts grow the tree forever.
const PREFIX_MAX_ENTRIES: usize = 1024;

/// One registration's quantizer state, shared (`Rc`) by every node that
/// registration created plus its tail. A partial-hit consumer adopts this
/// wholesale: the channel permutations make the producer's packed pages
/// decodable, the |Q| statistics seed the consumer's own accumulator. The
/// |Q| state is the producer's *whole-prompt* accumulator — for a partial
/// hit that is an approximation (the producer's suffix differed), which is
/// exactly the bounded error frozen-plan mode signs up for.
pub struct FrozenPlan {
    /// Snapshot identity (monotonic per tree) — nodes and tails reference
    /// plans by id in the snapshot codec so shared `Rc`s restore shared.
    pub(crate) id: u64,
    pub(crate) layers: usize,
    pub(crate) heads: usize,
    pub(crate) group: usize,
    pub(crate) d: usize,
    /// Channel permutation per `[layer][head]`; empty when the producer
    /// never planned (residual-only registration, `qt == 0`).
    pub(crate) plans: Vec<Vec<Vec<i32>>>,
    /// `(sum_abs, count)` |Q| accumulator state per `[layer][head]`.
    pub(crate) qstats: Vec<Vec<(Vec<f32>, f32)>>,
}

impl FrozenPlan {
    fn sidecar_bytes(&self) -> usize {
        let i32s = self.plans.iter().flatten().map(Vec::len).sum::<usize>();
        let f32s = self.qstats.iter().flatten().map(|(s, _)| s.len() + 1).sum::<usize>();
        4 * (i32s + f32s)
    }
}

/// One full G-token group of a registered prompt.
struct Node {
    /// Chain link of the parent group (the quantization-identity seed for
    /// depth-1 nodes, which have no parent node).
    parent: u64,
    /// 1-based group index: this node covers prompt tokens
    /// `[(depth-1)*G, depth*G)`.
    depth: usize,
    /// The span's tokens — every probe compares these (collision backstop).
    span: Vec<i32>,
    /// Chain links of resident child nodes (depth+1 extensions).
    children: Vec<u64>,
    /// One page per `(layer, kv-head)`, flattened `layer * heads + head`.
    pages: Vec<SharedLease>,
    frozen: Rc<FrozenPlan>,
    /// Tails anchored at this node (full-prompt registrations whose
    /// quantized window ends here).
    tails: usize,
    /// LRU stamp, bumped on every probe that traverses this node.
    stamp: u64,
}

impl Node {
    fn sheddable(&self) -> bool {
        self.children.is_empty() && self.tails == 0
    }
}

/// Full-prefill sidecar state, keyed by the full-prompt chain key. What a
/// `PrefixProbe::Full` consumer needs beyond the chain's pages: the
/// residual tail rows, the last-position logits, and (via `frozen`) the
/// plan/|Q| state.
struct TailState {
    t: usize,
    qt: usize,
    /// The registered prompt itself (full-hit token verify).
    tokens: Vec<i32>,
    /// Anchor node (chain link at depth `qt / G`); `None` when `qt == 0`
    /// (a residual-only prompt pins no pages).
    node: Option<u64>,
    frozen: Rc<FrozenPlan>,
    /// Residual K/V rows `[qt..t)` per `[layer][head]`, row-major `[rl, d]`.
    res_k: Vec<Vec<Vec<f32>>>,
    res_v: Vec<Vec<Vec<f32>>>,
    last_logits: Vec<f32>,
    stamp: u64,
}

impl TailState {
    fn sidecar_bytes(&self) -> usize {
        let f32s = self.res_k.iter().flatten().map(Vec::len).sum::<usize>()
            + self.res_v.iter().flatten().map(Vec::len).sum::<usize>()
            + self.last_logits.len();
        4 * (f32s + self.tokens.len())
    }
}

/// Everything a producer hands to [`RadixTree::register`]: the prompt, its
/// shared quantized pages `[layer][head][group]`, the quantizer state that
/// produced them, and the full-prefill sidecar. Assembled by
/// `RequestCache::register_prefix` — the only producer.
pub struct PrefixPayload {
    pub tokens: Vec<i32>,
    pub qt: usize,
    pub group: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub pages: Vec<Vec<Vec<SharedLease>>>,
    pub plans: Vec<Vec<Vec<i32>>>,
    pub qstats: Vec<Vec<(Vec<f32>, f32)>>,
    pub res_k: Vec<Vec<Vec<f32>>>,
    pub res_v: Vec<Vec<Vec<f32>>>,
    pub last_logits: Vec<f32>,
}

impl PrefixPayload {
    pub fn pages_count(&self) -> usize {
        self.pages.iter().flatten().map(Vec::len).sum()
    }
}

/// An assembled probe result: everything `RequestCache::install_prefix`
/// needs, with one cloned [`SharedLease`] per page — the clones pin the
/// pages between probe and install, so a pressure shed in between can
/// never free storage the consumer is about to adopt. For a partial match
/// `t == qt == matched_tokens` and the residual/logits are empty (the
/// consumer recomputes its own tail from the divergence seam).
pub struct PrefixMatch {
    pub t: usize,
    pub qt: usize,
    pub group: usize,
    pub d: usize,
    pub(crate) pages: Vec<Vec<Vec<SharedLease>>>,
    pub(crate) plans: Vec<Vec<Vec<i32>>>,
    pub(crate) qstats: Vec<Vec<(Vec<f32>, f32)>>,
    pub(crate) res_k: Vec<Vec<Vec<f32>>>,
    pub(crate) res_v: Vec<Vec<Vec<f32>>>,
    pub(crate) last_logits: Vec<f32>,
}

impl PrefixMatch {
    pub fn pages_count(&self) -> usize {
        self.pages.iter().flatten().map(Vec::len).sum()
    }

    /// Last-position logits of the registered prompt (full hits only — the
    /// consumer's first sampling input).
    pub fn last_logits(&self) -> &[f32] {
        &self.last_logits
    }
}

/// What [`RadixTree::lookup`] answers.
pub enum PrefixProbe {
    /// The whole prompt is registered: adopt pages + residual + logits,
    /// skip the prefill entirely (bit-exact).
    Full(PrefixMatch),
    /// A group-aligned strict prefix is registered: adopt its pages under
    /// the producer's frozen plan and resume prefill from the seam.
    Partial(PrefixMatch),
    Miss,
}

/// Counter-free probe answer for admission sizing ([`RadixTree::peek`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefixPeek {
    Full,
    /// Matched tokens (group-aligned, `> 0`).
    Partial(usize),
    Miss,
}

/// Counter snapshot for metrics (`coordinator::metrics::Metrics::observe_prefix`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Resident tails (full-prompt registrations).
    pub entries: usize,
    /// Resident interior nodes (one per shared G-token group).
    pub nodes: usize,
    pub pages_pinned: usize,
    /// Full-prompt hits (entire prefill skipped).
    pub hits: u64,
    /// Deepest-prefix hits (prefill resumed from the seam, frozen plan).
    pub partial_hits: u64,
    pub misses: u64,
    pub insertions: u64,
    /// Tails + nodes shed — LRU cap at insert, pool pressure, corruption.
    pub evictions: u64,
    /// Registrations refused because the payload alone exceeds the page cap.
    pub rejected: u64,
    /// Probes whose chain link matched a resident node/tail but whose
    /// tokens did not — a hash collision, recorded and never served.
    pub collisions: u64,
    /// Registrations refused because their channel plans disagreed with a
    /// resident node on the shared path (a producer that did NOT adopt the
    /// frozen plan — mixing its pages with the resident plan would decode
    /// garbage, so the new chain is refused, never spliced).
    pub plan_conflicts: u64,
    /// Deployment bytes consumers adopted instead of leasing privately
    /// (pages adopted on full + partial hits × bytes/page), cumulative.
    pub bytes_deduped: u64,
    /// Off-pool bytes held by sidecars (span/prompt copies, residual
    /// snapshots, logits, frozen plans).
    pub sidecar_bytes: usize,
}

/// The tree itself. Coordinator-only by design — the server owns one
/// behind `Rc<RefCell<…>>` shared with the engine and it never crosses a
/// worker-pool thread boundary (probes, registrations, and
/// pressure-shedding all run on the coordinator between parallel phases),
/// so it needs no lock even though the leases it pins are `Arc`s.
pub struct RadixTree {
    nodes: HashMap<u64, Node>,
    tails: HashMap<u64, TailState>,
    max_pages: usize,
    max_entries: usize,
    page_deploy_bytes: usize,
    clock: u64,
    next_plan_id: u64,
    pinned_pages: usize,
    sidecar_bytes: usize,
    hits: u64,
    partial_hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    rejected: u64,
    collisions: u64,
    plan_conflicts: u64,
    bytes_deduped: u64,
}

impl RadixTree {
    /// `max_pages` caps the pool pages nodes may pin (tail COUNT is
    /// additionally capped at [`PREFIX_MAX_ENTRIES`]); `page_deploy_bytes`
    /// is the pool's per-page charge (for the bytes-deduped gauge).
    pub fn new(max_pages: usize, page_deploy_bytes: usize) -> RadixTree {
        RadixTree {
            nodes: HashMap::new(),
            tails: HashMap::new(),
            max_pages,
            max_entries: PREFIX_MAX_ENTRIES,
            page_deploy_bytes,
            clock: 0,
            next_plan_id: 0,
            pinned_pages: 0,
            sidecar_bytes: 0,
            hits: 0,
            partial_hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            rejected: 0,
            collisions: 0,
            plan_conflicts: 0,
            bytes_deduped: 0,
        }
    }

    /// Is a full-prompt tail registered under `key`? (Corrupt-fault draws
    /// gate on residency, like the flat index did.)
    pub fn contains(&self, key: u64) -> bool {
        self.tails.contains_key(&key)
    }

    /// Number of full groups of `prompt` eligible for a partial walk: never
    /// past the consumer's own quantized-window end (`qt_c`), and never the
    /// whole prompt (the resumed prefill must recompute at least the last
    /// token so it can project logits).
    pub fn partial_walk_groups(qt_c: usize, t: usize, group: usize) -> usize {
        if group == 0 || t == 0 {
            return 0;
        }
        qt_c.min(t - 1) / group
    }

    /// Counter-free probe (admission sizing uses this so a submit-time
    /// estimate does not inflate the hit/miss telemetry). `max_groups`
    /// bounds the partial walk (see [`RadixTree::partial_walk_groups`]);
    /// pass 0 to consider full hits only (frozen-plan mode disabled).
    pub fn peek(&self, seed: u64, prompt: &[i32], group: usize, max_groups: usize) -> PrefixPeek {
        let full_key = prompt_chain_key(seed, prompt, group);
        if let Some(tail) = self.tails.get(&full_key) {
            if tail.tokens == prompt {
                return PrefixPeek::Full;
            }
        }
        let matched = self.walk(seed, prompt, group, max_groups);
        if matched == 0 {
            PrefixPeek::Miss
        } else {
            PrefixPeek::Partial(matched * group)
        }
    }

    /// Deepest verified match, in groups (0 = none). Pure walk, no
    /// counters, no stamps.
    fn walk(&self, seed: u64, prompt: &[i32], group: usize, max_groups: usize) -> usize {
        let cap = max_groups.min(if group == 0 { 0 } else { prompt.len() / group });
        if cap == 0 {
            return 0;
        }
        let links = prompt_chain_links(seed, prompt, group);
        let mut matched = 0;
        for g in 0..cap {
            let Some(node) = self.nodes.get(&links[g]) else { break };
            if node.span != prompt[g * group..(g + 1) * group] {
                break;
            }
            matched = g + 1;
        }
        matched
    }

    /// The consuming probe. Full-prompt tails are checked first (bit-exact
    /// adoption); otherwise the chain is walked to the deepest verified
    /// node and answered as a partial match under the producer's frozen
    /// plan. Either hit stamps the whole consumed path most-recently-used
    /// and credits the adopted pages as deduped bytes; token mismatches on
    /// a resident link are counted as collisions and never served.
    pub fn lookup(
        &mut self,
        seed: u64,
        prompt: &[i32],
        group: usize,
        max_groups: usize,
    ) -> PrefixProbe {
        self.clock += 1;
        let clock = self.clock;
        let full_key = prompt_chain_key(seed, prompt, group);
        match self.tails.get_mut(&full_key) {
            Some(tail) if tail.tokens == prompt => {
                tail.stamp = clock;
                let (t, qt, node) = (tail.t, tail.qt, tail.node);
                let frozen = tail.frozen.clone();
                let res_k = tail.res_k.clone();
                let res_v = tail.res_v.clone();
                let last_logits = tail.last_logits.clone();
                let pages = self.stamp_and_collect(node, qt / group.max(1), clock);
                let m = PrefixMatch {
                    t,
                    qt,
                    group,
                    d: frozen.d,
                    pages,
                    plans: frozen.plans.clone(),
                    qstats: frozen.qstats.clone(),
                    res_k,
                    res_v,
                    last_logits,
                };
                self.hits += 1;
                self.bytes_deduped += (m.pages_count() * self.page_deploy_bytes) as u64;
                return PrefixProbe::Full(m);
            }
            Some(_) => self.collisions += 1,
            None => {}
        }
        let matched = self.walk(seed, prompt, group, max_groups);
        if matched == 0 {
            self.misses += 1;
            return PrefixProbe::Miss;
        }
        let links = prompt_chain_links(seed, prompt, group);
        let anchor = links[matched - 1];
        let frozen = self.nodes[&anchor].frozen.clone();
        let (layers, heads) = (frozen.layers, frozen.heads);
        let pages = self.stamp_and_collect(Some(anchor), matched, clock);
        let m = PrefixMatch {
            t: matched * group,
            qt: matched * group,
            group,
            d: frozen.d,
            pages,
            plans: frozen.plans.clone(),
            qstats: frozen.qstats.clone(),
            res_k: vec![vec![Vec::new(); heads]; layers],
            res_v: vec![vec![Vec::new(); heads]; layers],
            last_logits: Vec::new(),
        };
        self.partial_hits += 1;
        self.bytes_deduped += (m.pages_count() * self.page_deploy_bytes) as u64;
        PrefixProbe::Partial(m)
    }

    /// Stamp the `groups`-deep chain ending at `anchor` and clone its pages
    /// back into `[layer][head][group]` shape. Chain integrity (every
    /// ancestor resident) is a structural invariant — a tail pins its
    /// anchor, an anchor's ancestors all have children — so absence here is
    /// a bug, not a request-path error.
    fn stamp_and_collect(
        &mut self,
        anchor: Option<u64>,
        groups: usize,
        clock: u64,
    ) -> Vec<Vec<Vec<SharedLease>>> {
        let Some(anchor) = anchor else { return Vec::new() };
        let (layers, heads) = {
            let f = &self.nodes[&anchor].frozen;
            (f.layers, f.heads)
        };
        let mut pages = vec![vec![vec![None; groups]; heads]; layers];
        let mut key = anchor;
        for g in (0..groups).rev() {
            let node = self.nodes.get_mut(&key).expect("chain ancestor missing");
            debug_assert_eq!(node.depth, g + 1, "chain depth mismatch");
            node.stamp = clock;
            for l in 0..layers {
                for h in 0..heads {
                    pages[l][h][g] = Some(node.pages[l * heads + h].clone());
                }
            }
            key = node.parent;
        }
        pages
            .into_iter()
            .map(|lh| {
                lh.into_iter()
                    .map(|row| row.into_iter().map(|p| p.expect("page collected")).collect())
                    .collect()
            })
            .collect()
    }

    /// Stamp a verified path (and, if resident, the full-prompt tail)
    /// most-recently-used WITHOUT recording a hit — the admission pass
    /// touches the ENTIRE node path a claim rests on, so its own
    /// pressure-shedding loop cannot evict an interior node out from under
    /// the request it is about to serve.
    pub fn touch_path(&mut self, seed: u64, prompt: &[i32], group: usize, max_groups: usize) {
        self.clock += 1;
        let clock = self.clock;
        let full_key = prompt_chain_key(seed, prompt, group);
        let mut tail_groups = None;
        if let Some(tail) = self.tails.get_mut(&full_key) {
            if tail.tokens == prompt {
                tail.stamp = clock;
                tail_groups = Some(tail.qt / group.max(1));
            }
        }
        let matched = match tail_groups {
            // a resident full hit pins its whole chain regardless of the
            // partial-walk cap
            Some(g) => g,
            None => self.walk(seed, prompt, group, max_groups),
        };
        if matched == 0 {
            return;
        }
        let links = prompt_chain_links(seed, prompt, group);
        for link in &links[..matched] {
            if let Some(node) = self.nodes.get_mut(link) {
                node.stamp = clock;
            }
        }
    }

    /// Can a payload pinning `pages` pool pages ever be accepted? The
    /// producer consults this BEFORE assembling (deep-copying) a payload,
    /// so an over-cap prompt costs nothing.
    pub fn would_accept(&self, pages: usize) -> bool {
        pages <= self.max_pages
    }

    /// Register a full prefill. The chain is verified first: a resident
    /// node whose span tokens differ is a collision, one whose frozen plan
    /// differs from the payload's is a plan conflict — either refuses the
    /// whole registration (dropping the payload's references) rather than
    /// splice inconsistent state into a shared chain. New nodes are created
    /// for absent groups only (a follower that adopted the producer's
    /// frozen plan extends the chain with just its divergent suffix), the
    /// tail is anchored at the deepest node, and LRU shedding makes room
    /// under the page and entry caps — never shedding the path being
    /// registered. Returns false on duplicate (refreshing recency),
    /// collision, plan conflict, or an over-cap payload.
    pub fn register(&mut self, seed: u64, p: PrefixPayload) -> bool {
        let full_key = prompt_chain_key(seed, &p.tokens, p.group);
        if let Some(tail) = self.tails.get_mut(&full_key) {
            self.clock += 1;
            tail.stamp = self.clock;
            return false;
        }
        let total_pages = p.pages_count();
        if total_pages > self.max_pages {
            self.rejected += 1;
            return false;
        }
        let group = p.group.max(1);
        let n_groups = p.qt / group;
        let links = prompt_chain_links(seed, &p.tokens, p.group);
        // pass 1: verify the resident part of the chain, count absent nodes
        let mut absent = 0usize;
        for g in 0..n_groups {
            match self.nodes.get(&links[g]) {
                Some(node) => {
                    if node.span != p.tokens[g * group..(g + 1) * group] {
                        self.collisions += 1;
                        return false;
                    }
                    if node.frozen.plans != p.plans {
                        self.plan_conflicts += 1;
                        return false;
                    }
                }
                None => absent += 1,
            }
        }
        let per_node = p.layers * p.heads;
        let need = absent * per_node;
        // pass 2: stamp the reused path MRU, then shed around it until the
        // new nodes and the tail fit. Exhaustion cannot strand us over cap:
        // whatever survives shedding is exactly our own (excluded) path,
        // and path + need = total_pages ≤ max_pages was checked above.
        self.clock += 1;
        let clock = self.clock;
        let mut path: HashSet<u64> = HashSet::new();
        for g in 0..n_groups {
            if let Some(node) = self.nodes.get_mut(&links[g]) {
                node.stamp = clock;
                path.insert(links[g]);
            }
        }
        while self.pinned_pages + need > self.max_pages || self.tails.len() >= self.max_entries {
            if !self.shed_lru_excluding(&path) {
                break;
            }
        }
        // pass 3: create the absent nodes and anchor the tail
        let frozen = Rc::new(FrozenPlan {
            id: self.next_plan_id,
            layers: p.layers,
            heads: p.heads,
            group: p.group,
            d: p.d,
            plans: p.plans,
            qstats: p.qstats,
        });
        self.next_plan_id += 1;
        self.sidecar_bytes += frozen.sidecar_bytes();
        for g in 0..n_groups {
            let key = links[g];
            if self.nodes.contains_key(&key) {
                continue;
            }
            let parent = if g == 0 { seed } else { links[g - 1] };
            if g > 0 {
                // invariant: pass 1 verified every ancestor resident or
                // created by this loop in depth order
                let pn = self.nodes.get_mut(&parent).expect("parent node resident");
                pn.children.push(key);
            }
            let mut pages = Vec::with_capacity(per_node);
            for l in 0..p.layers {
                for h in 0..p.heads {
                    pages.push(p.pages[l][h][g].clone());
                }
            }
            let span = p.tokens[g * group..(g + 1) * group].to_vec();
            self.sidecar_bytes += 4 * span.len();
            self.pinned_pages += per_node;
            self.nodes.insert(
                key,
                Node {
                    parent,
                    depth: g + 1,
                    span,
                    children: Vec::new(),
                    pages,
                    frozen: frozen.clone(),
                    tails: 0,
                    stamp: clock,
                },
            );
        }
        let anchor = if n_groups > 0 { Some(links[n_groups - 1]) } else { None };
        if let Some(a) = anchor {
            self.nodes.get_mut(&a).expect("anchor resident").tails += 1;
        }
        let tail = TailState {
            t: p.tokens.len(),
            qt: p.qt,
            tokens: p.tokens,
            node: anchor,
            frozen,
            res_k: p.res_k,
            res_v: p.res_v,
            last_logits: p.last_logits,
            stamp: clock,
        };
        self.sidecar_bytes += tail.sidecar_bytes();
        self.tails.insert(full_key, tail);
        self.insertions += 1;
        true
    }

    /// Release accounting for a frozen plan about to lose a holder: the
    /// caller still owns `f`, so a strong count of 1 means this drop is the
    /// last and its sidecar charge retires.
    fn release_frozen(&mut self, f: &Rc<FrozenPlan>) {
        if Rc::strong_count(f) == 1 {
            self.sidecar_bytes -= f.sidecar_bytes();
        }
    }

    /// Remove one node (must be sheddable), unlinking it from its parent.
    fn remove_node(&mut self, key: u64) {
        let node = self.nodes.remove(&key).expect("node resident");
        debug_assert!(node.sheddable(), "removing a pinned node");
        self.pinned_pages -= node.pages.len();
        self.sidecar_bytes -= 4 * node.span.len();
        if node.depth > 1 {
            if let Some(parent) = self.nodes.get_mut(&node.parent) {
                parent.children.retain(|&c| c != key);
            }
        }
        self.release_frozen(&node.frozen);
        self.evictions += 1;
    }

    /// Remove one tail (sidecar + anchor unpin). Does NOT cascade into its
    /// chain: bare node chains still serve partial hits and erode leaf-
    /// first under LRU pressure like any other cold state.
    fn remove_tail(&mut self, key: u64) {
        let tail = self.tails.remove(&key).expect("tail resident");
        self.sidecar_bytes -= tail.sidecar_bytes();
        if let Some(a) = tail.node {
            self.nodes.get_mut(&a).expect("anchor resident").tails -= 1;
        }
        self.release_frozen(&tail.frozen);
        self.evictions += 1;
    }

    /// Drop the least-recently-used sheddable entity — a tail or a *leaf*
    /// node (childless, tailless; interior nodes and anchors are pinned by
    /// their dependents, so chains erode from the deep end). The server
    /// calls this under pool pressure — retention never outranks a live
    /// request's flush. Returns false when nothing can be shed.
    pub fn shed_lru(&mut self) -> bool {
        self.shed_lru_excluding(&HashSet::new())
    }

    fn shed_lru_excluding(&mut self, exclude: &HashSet<u64>) -> bool {
        // (stamp, kind, key) min — deterministic under ties
        let tail = self.tails.iter().map(|(&k, t)| (t.stamp, 0u8, k)).min();
        let node = self
            .nodes
            .iter()
            .filter(|(k, n)| n.sheddable() && !exclude.contains(k))
            .map(|(&k, n)| (n.stamp, 1u8, k))
            .min();
        match (tail, node) {
            (None, None) => false,
            (Some(t), None) => {
                self.remove_tail(t.2);
                true
            }
            (None, Some(n)) => {
                self.remove_node(n.2);
                true
            }
            (Some(t), Some(n)) => {
                if t < n {
                    self.remove_tail(t.2);
                } else {
                    self.remove_node(n.2);
                }
                true
            }
        }
    }

    /// Drop a distrusted full-prompt registration — the corruption/
    /// verify-fail path (today reached via injected `FaultSite::PrefixCorrupt`
    /// faults): the tail is removed and its chain is cascaded from the
    /// anchor upward, removing every node only this registration used
    /// (nodes with other children or tails survive — they serve other
    /// chains). Recorded exactly like a chain-key collision (a miss, never
    /// served). Returns false when the key is not resident.
    pub fn discard_corrupt(&mut self, key: u64) -> bool {
        if !self.tails.contains_key(&key) {
            return false;
        }
        let anchor = self.tails[&key].node;
        self.remove_tail(key);
        let mut cursor = anchor;
        while let Some(k) = cursor {
            let Some(node) = self.nodes.get(&k) else { break };
            if !node.sheddable() {
                break;
            }
            cursor = if node.depth > 1 { Some(node.parent) } else { None };
            self.remove_node(k);
        }
        self.collisions += 1;
        self.misses += 1;
        true
    }

    /// Shed every node holding page `id` AND everything below it — the
    /// scrub's quarantine path: a corrupt interior span makes every
    /// descendant's prefix unreachable, so the whole subtree (and any tail
    /// anchored inside it) goes. Dependent tails are recorded per
    /// [`RadixTree::discard_corrupt`]. Returns the number of entities shed.
    pub fn shed_page(&mut self, id: usize) -> usize {
        let mut infected: Vec<u64> = self
            .nodes
            .iter()
            .filter(|(_, n)| n.pages.iter().any(|s| s.page().id() == id))
            .map(|(&k, _)| k)
            .collect();
        // expand to full subtrees
        let mut doomed: HashSet<u64> = HashSet::new();
        while let Some(k) = infected.pop() {
            if !doomed.insert(k) {
                continue;
            }
            if let Some(n) = self.nodes.get(&k) {
                infected.extend(n.children.iter().copied());
            }
        }
        if doomed.is_empty() {
            return 0;
        }
        let tail_keys: Vec<u64> = self
            .tails
            .iter()
            .filter(|(_, t)| t.node.is_some_and(|a| doomed.contains(&a)))
            .map(|(&k, _)| k)
            .collect();
        let mut shed = 0usize;
        for k in &tail_keys {
            self.remove_tail(*k);
            self.collisions += 1;
            self.misses += 1;
            shed += 1;
        }
        // remove deepest-first so parents shed as leaves
        let mut order: Vec<u64> = doomed.iter().copied().collect();
        order.sort_by_key(|k| std::cmp::Reverse(self.nodes[k].depth));
        for k in order {
            self.remove_node(k);
            shed += 1;
        }
        shed
    }

    /// Append the pool identity of every page pinned by any node (see
    /// [`SharedLease::page_id`]) — invariant audits dedup these against
    /// the ids live caches hold.
    pub fn collect_page_ids(&self, out: &mut Vec<usize>) {
        for n in self.nodes.values() {
            for s in &n.pages {
                out.push(s.page_id());
            }
        }
    }

    /// Drop everything (all pinned pages release).
    pub fn clear(&mut self) {
        self.evictions += (self.tails.len() + self.nodes.len()) as u64;
        self.tails.clear();
        self.nodes.clear();
        self.pinned_pages = 0;
        self.sidecar_bytes = 0;
    }

    /// Resident tails (full-prompt registrations).
    pub fn len(&self) -> usize {
        self.tails.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tails.is_empty() && self.nodes.is_empty()
    }

    /// Resident interior nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Pool pages currently pinned by nodes.
    pub fn pages_pinned(&self) -> usize {
        self.pinned_pages
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            entries: self.tails.len(),
            nodes: self.nodes.len(),
            pages_pinned: self.pinned_pages,
            hits: self.hits,
            partial_hits: self.partial_hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            rejected: self.rejected,
            collisions: self.collisions,
            plan_conflicts: self.plan_conflicts,
            bytes_deduped: self.bytes_deduped,
            sidecar_bytes: self.sidecar_bytes,
        }
    }

    /// Canonical node walk order — (depth, key) — shared by
    /// [`RadixTree::for_each_page`] and the snapshot codec, so the
    /// snapshot's page-numbering pass and the live scrub visit pages in
    /// the same deterministic sequence.
    fn node_order(&self) -> Vec<u64> {
        let mut order: Vec<u64> = self.nodes.keys().copied().collect();
        order.sort_by_key(|k| (self.nodes[k].depth, *k));
        order
    }

    /// Visit every page pinned by any node, in canonical (depth, key)
    /// order.
    pub fn for_each_page(&self, f: &mut dyn FnMut(&Page)) {
        for k in self.node_order() {
            for s in &self.nodes[&k].pages {
                f(s.page());
            }
        }
    }

    /// Structural self-check for `Server::check_invariants`: recomputed
    /// page pins match the incremental counter, parent/child links are
    /// coherent, every tail's anchor chain is resident, and per-node tail
    /// counts agree with the tails map.
    pub fn audit(&self) -> Result<(), String> {
        let pinned: usize = self.nodes.values().map(|n| n.pages.len()).sum();
        if pinned != self.pinned_pages {
            return Err(format!(
                "radix pinned_pages counter {} != recomputed {}",
                self.pinned_pages, pinned
            ));
        }
        let mut anchored: HashMap<u64, usize> = HashMap::new();
        for (key, tail) in &self.tails {
            if let Some(a) = tail.node {
                let Some(node) = self.nodes.get(&a) else {
                    return Err(format!("tail {key:#x} anchored at missing node {a:#x}"));
                };
                if node.depth * tail.frozen.group.max(1) != tail.qt {
                    return Err(format!("tail {key:#x} anchor depth mismatch"));
                }
                *anchored.entry(a).or_insert(0) += 1;
            } else if tail.qt != 0 {
                return Err(format!("tail {key:#x} has qt {} but no anchor", tail.qt));
            }
        }
        for (&key, node) in &self.nodes {
            if node.tails != anchored.get(&key).copied().unwrap_or(0) {
                return Err(format!("node {key:#x} tail refcount drift"));
            }
            if node.depth > 1 {
                let Some(parent) = self.nodes.get(&node.parent) else {
                    return Err(format!("node {key:#x} orphaned (parent missing)"));
                };
                if parent.depth + 1 != node.depth {
                    return Err(format!("node {key:#x} depth discontinuity"));
                }
                if !parent.children.contains(&key) {
                    return Err(format!("node {key:#x} missing from parent's children"));
                }
            }
            for &c in &node.children {
                if !self.nodes.contains_key(&c) {
                    return Err(format!("node {key:#x} lists missing child {c:#x}"));
                }
            }
        }
        Ok(())
    }

    // --- snapshot codec ----------------------------------------------

    /// Serialize the whole tree: the frozen-plan table (unique by id), the
    /// nodes in canonical (depth, key) order (parents always precede
    /// children), the tails in key order, then the LRU clock and counters.
    /// `serial_of` maps a page's pool identity ([`Page::id`]) to the
    /// serial the snapshot's page section wrote it under — the server owns
    /// that numbering (pages shared between a slot and the tree are
    /// written once).
    pub fn write_snap<W: std::io::Write>(
        &self,
        w: &mut SnapWriter<W>,
        serial_of: &mut dyn FnMut(usize) -> u32,
    ) -> SnapResult<()> {
        // unique frozen plans, by id
        let mut plans: HashMap<u64, &Rc<FrozenPlan>> = HashMap::new();
        for n in self.nodes.values() {
            plans.entry(n.frozen.id).or_insert(&n.frozen);
        }
        for t in self.tails.values() {
            plans.entry(t.frozen.id).or_insert(&t.frozen);
        }
        let mut plan_order: Vec<u64> = plans.keys().copied().collect();
        plan_order.sort_unstable();
        w.usize(plan_order.len())?;
        for id in &plan_order {
            let f = plans[id];
            w.u64(f.id)?;
            for v in [f.layers, f.heads, f.group, f.d] {
                w.usize(v)?;
            }
            w.bool(!f.plans.is_empty())?;
            w.bool(!f.qstats.is_empty())?;
            if !f.plans.is_empty() {
                for l in 0..f.layers {
                    for h in 0..f.heads {
                        w.slice_i32(&f.plans[l][h])?;
                    }
                }
            }
            if !f.qstats.is_empty() {
                for l in 0..f.layers {
                    for h in 0..f.heads {
                        w.slice_f32(&f.qstats[l][h].0)?;
                        w.f32(f.qstats[l][h].1)?;
                    }
                }
            }
        }
        let order = self.node_order();
        w.usize(order.len())?;
        for key in &order {
            let n = &self.nodes[key];
            w.u64(*key)?;
            w.u64(n.parent)?;
            w.usize(n.depth)?;
            w.u64(n.stamp)?;
            w.slice_i32(&n.span)?;
            w.u64(n.frozen.id)?;
            w.usize(n.pages.len())?;
            for s in &n.pages {
                w.u32(serial_of(s.page().id()))?;
            }
        }
        let mut tail_order: Vec<u64> = self.tails.keys().copied().collect();
        tail_order.sort_unstable();
        w.usize(tail_order.len())?;
        for key in &tail_order {
            let t = &self.tails[key];
            w.u64(*key)?;
            w.u64(t.stamp)?;
            w.usize(t.t)?;
            w.usize(t.qt)?;
            w.slice_i32(&t.tokens)?;
            w.bool(t.node.is_some())?;
            if let Some(a) = t.node {
                w.u64(a)?;
            }
            w.u64(t.frozen.id)?;
            for l in 0..t.frozen.layers {
                for h in 0..t.frozen.heads {
                    w.slice_f32(&t.res_k[l][h])?;
                    w.slice_f32(&t.res_v[l][h])?;
                }
            }
            w.slice_f32(&t.last_logits)?;
        }
        w.u64(self.clock)?;
        w.u64(self.next_plan_id)?;
        for c in [
            self.hits,
            self.partial_hits,
            self.misses,
            self.insertions,
            self.evictions,
            self.rejected,
            self.collisions,
            self.plan_conflicts,
            self.bytes_deduped,
        ] {
            w.u64(c)?;
        }
        Ok(())
    }

    /// Rebuild the tree from a snapshot into this (freshly constructed)
    /// instance. `resolve` turns a page serial into a [`SharedLease`] on
    /// the reloaded page — answering `None` for a serial whose payload
    /// failed its checksum. A node touching any such serial is dropped
    /// along with its whole subtree and every tail anchored inside it
    /// (recorded per [`RadixTree::discard_corrupt`] / node evictions);
    /// structural damage to the stream itself is a hard `Err`. Returns the
    /// number of entities dropped.
    pub fn read_snap<R: std::io::Read>(
        &mut self,
        r: &mut SnapReader<R>,
        resolve: &mut dyn FnMut(u32) -> Option<SharedLease>,
    ) -> SnapResult<usize> {
        let n_plans = r.len("radix plan count")?;
        let mut plans: HashMap<u64, Rc<FrozenPlan>> = HashMap::new();
        for _ in 0..n_plans {
            let id = r.u64("radix plan id")?;
            let layers = r.usize("radix plan layers")?;
            let heads = r.usize("radix plan heads")?;
            let group = r.usize("radix plan group")?;
            let d = r.usize("radix plan d")?;
            let has_plans = r.bool("radix plan flag")?;
            let has_qstats = r.bool("radix qstat flag")?;
            let mut pl: Vec<Vec<Vec<i32>>> = Vec::new();
            if has_plans {
                for _ in 0..layers {
                    let mut row = Vec::with_capacity(heads);
                    for _ in 0..heads {
                        row.push(r.vec_i32("radix plan perm")?);
                    }
                    pl.push(row);
                }
            }
            let mut qs: Vec<Vec<(Vec<f32>, f32)>> = Vec::new();
            if has_qstats {
                for _ in 0..layers {
                    let mut row = Vec::with_capacity(heads);
                    for _ in 0..heads {
                        let s = r.vec_f32("radix qstat sums")?;
                        let c = r.f32("radix qstat count")?;
                        row.push((s, c));
                    }
                    qs.push(row);
                }
            }
            let f = Rc::new(FrozenPlan { id, layers, heads, group, d, plans: pl, qstats: qs });
            self.sidecar_bytes += f.sidecar_bytes();
            plans.insert(id, f);
        }
        let mut dropped = 0usize;
        let mut poisoned: HashSet<u64> = HashSet::new();
        let n_nodes = r.len("radix node count")?;
        for _ in 0..n_nodes {
            let key = r.u64("radix node key")?;
            let parent = r.u64("radix node parent")?;
            let depth = r.usize("radix node depth")?;
            let stamp = r.u64("radix node stamp")?;
            let span = r.vec_i32("radix node span")?;
            let plan_id = r.u64("radix node plan")?;
            let n_pages = r.len("radix node pages")?;
            let mut pages = Vec::with_capacity(n_pages);
            let mut poison = false;
            for _ in 0..n_pages {
                let serial = r.u32("radix node page serial")?;
                match resolve(serial) {
                    Some(s) => pages.push(s),
                    None => poison = true,
                }
            }
            let Some(frozen) = plans.get(&plan_id) else {
                return Err(corrupt(format!("radix node {key:#x}: unknown plan {plan_id}")));
            };
            if depth == 0 || span.len() != frozen.group {
                return Err(corrupt(format!(
                    "radix node {key:#x}: depth {depth} / span {} inconsistent with group {}",
                    span.len(),
                    frozen.group
                )));
            }
            // nodes arrive parent-first: a poisoned or dropped parent
            // orphans the whole subtree (its prefix is unreachable)
            if poison || (depth > 1 && (poisoned.contains(&parent) || !self.nodes.contains_key(&parent))) {
                poisoned.insert(key);
                dropped += 1;
                continue;
            }
            if depth > 1 {
                self.nodes.get_mut(&parent).expect("parent resident").children.push(key);
            }
            self.pinned_pages += pages.len();
            self.sidecar_bytes += 4 * span.len();
            self.nodes.insert(
                key,
                Node {
                    parent,
                    depth,
                    span,
                    children: Vec::new(),
                    pages,
                    frozen: frozen.clone(),
                    tails: 0,
                    stamp,
                },
            );
        }
        let n_tails = r.len("radix tail count")?;
        let mut dropped_tails = 0usize;
        for _ in 0..n_tails {
            let key = r.u64("radix tail key")?;
            let stamp = r.u64("radix tail stamp")?;
            let t = r.usize("radix tail t")?;
            let qt = r.usize("radix tail qt")?;
            let tokens = r.vec_i32("radix tail tokens")?;
            let anchor = if r.bool("radix tail anchor flag")? {
                Some(r.u64("radix tail anchor")?)
            } else {
                None
            };
            let plan_id = r.u64("radix tail plan")?;
            let Some(frozen) = plans.get(&plan_id).cloned() else {
                return Err(corrupt(format!("radix tail {key:#x}: unknown plan {plan_id}")));
            };
            if qt > t || tokens.len() != t || (frozen.group > 0 && qt % frozen.group != 0) {
                return Err(corrupt(format!(
                    "radix tail {key:#x}: qt {qt} inconsistent with t {t}, group {}",
                    frozen.group
                )));
            }
            let mut res_k = Vec::with_capacity(frozen.layers);
            let mut res_v = Vec::with_capacity(frozen.layers);
            for _ in 0..frozen.layers {
                let mut lk = Vec::with_capacity(frozen.heads);
                let mut lv = Vec::with_capacity(frozen.heads);
                for _ in 0..frozen.heads {
                    let rk = r.vec_f32("radix tail residual keys")?;
                    let rv = r.vec_f32("radix tail residual values")?;
                    if rk.len() != (t - qt) * frozen.d || rv.len() != (t - qt) * frozen.d {
                        return Err(corrupt(format!(
                            "radix tail {key:#x}: residual rows do not cover {} tail tokens",
                            t - qt
                        )));
                    }
                    lk.push(rk);
                    lv.push(rv);
                }
                res_k.push(lk);
                res_v.push(lv);
            }
            let last_logits = r.vec_f32("radix tail logits")?;
            // a tail whose anchor was dropped (poisoned subtree) drops too
            let anchor_ok = match anchor {
                Some(a) => self.nodes.contains_key(&a),
                None => qt == 0,
            };
            if !anchor_ok {
                dropped += 1;
                dropped_tails += 1;
                continue;
            }
            if let Some(a) = anchor {
                self.nodes.get_mut(&a).expect("anchor resident").tails += 1;
            }
            let tail =
                TailState { t, qt, tokens, node: anchor, frozen, res_k, res_v, last_logits, stamp };
            self.sidecar_bytes += tail.sidecar_bytes();
            self.tails.insert(key, tail);
        }
        // plans nobody referenced (all holders dropped) retire their charge
        for f in plans.values() {
            if Rc::strong_count(f) == 1 {
                self.sidecar_bytes -= f.sidecar_bytes();
            }
        }
        self.clock = r.u64("radix clock")?;
        self.next_plan_id = r.u64("radix next_plan_id")?;
        self.hits = r.u64("radix hits")?;
        self.partial_hits = r.u64("radix partial_hits")?;
        self.misses = r.u64("radix misses")?;
        self.insertions = r.u64("radix insertions")?;
        self.evictions = r.u64("radix evictions")?;
        self.rejected = r.u64("radix rejected")?;
        self.collisions = r.u64("radix collisions")?;
        self.plan_conflicts = r.u64("radix plan_conflicts")?;
        self.bytes_deduped = r.u64("radix bytes_deduped")?;
        self.evictions += dropped as u64;
        self.collisions += dropped_tails as u64;
        self.misses += dropped_tails as u64;
        Ok(dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::pool::{KvPool, PageRef};
    use crate::quant::window::TierSpec;
    use crate::util::snapshot::{SnapReader, SnapWriter};

    const G: usize = 4; // group (tokens per page/node span)
    const D: usize = 32; // head dim (pool layout requires a packable spec)

    fn mixspec() -> TierSpec {
        TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }
    }

    fn pool(max: Option<usize>) -> KvPool {
        KvPool::for_specs([&mixspec()], D, G, max)
    }

    fn shared_page(pool: &KvPool) -> SharedLease {
        let (p, extra) = PageRef::Private(pool.lease().unwrap()).into_shared();
        drop(p);
        extra
    }

    /// A 1-layer / 1-head payload over `tokens` with `qt` quantized tokens
    /// (qt / G fresh pool pages) and an identity channel plan.
    fn payload(pool: &KvPool, tokens: Vec<i32>, qt: usize) -> PrefixPayload {
        assert!(qt % G == 0 && qt <= tokens.len());
        let groups = qt / G;
        let rl = tokens.len() - qt;
        PrefixPayload {
            qt,
            group: G,
            d: D,
            layers: 1,
            heads: 1,
            pages: vec![vec![(0..groups).map(|_| shared_page(pool)).collect()]],
            plans: if qt > 0 { vec![vec![(0..D as i32).collect()]] } else { Vec::new() },
            qstats: vec![vec![(vec![0.5; D], qt as f32)]],
            res_k: vec![vec![vec![0.25; rl * D]]],
            res_v: vec![vec![vec![0.75; rl * D]]],
            last_logits: vec![1.0, -2.0],
            tokens,
        }
    }

    #[test]
    fn register_then_full_partial_and_miss_probes() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 11u64;
        let prompt: Vec<i32> = (0..12).collect(); // qt 8 = 2 groups, rl 4
        assert!(tree.register(seed, payload(&pool, prompt.clone(), 8)));
        assert_eq!((tree.len(), tree.node_count(), tree.pages_pinned()), (1, 2, 2));
        assert_eq!(pool.leased(), 2, "payload drop leaves only node pins");
        tree.audit().unwrap();

        // full hit: bit-exact sidecar back
        let m = match tree.lookup(seed, &prompt, G, 0) {
            PrefixProbe::Full(m) => m,
            _ => panic!("expected full"),
        };
        assert_eq!((m.t, m.qt, m.pages_count()), (12, 8, 2));
        assert_eq!(m.last_logits(), &[1.0, -2.0]);
        assert_eq!(m.res_k[0][0].len(), 4 * D);
        drop(m);

        // partial: same first 2 groups, divergent third
        let mut p2: Vec<i32> = (0..12).collect();
        for x in p2.iter_mut().skip(8) {
            *x += 100;
        }
        let m = match tree.lookup(seed, &p2, G, 2) {
            PrefixProbe::Partial(m) => m,
            _ => panic!("expected partial"),
        };
        assert_eq!((m.t, m.qt, m.pages_count()), (8, 8, 2));
        assert!(m.last_logits().is_empty() && m.res_k[0][0].is_empty());
        drop(m);

        // a cap of 0 (frozen-plan mode off) turns the same probe into a miss
        assert!(matches!(tree.lookup(seed, &p2, G, 0), PrefixProbe::Miss));
        // a different seed never sees the chain
        assert!(matches!(tree.lookup(seed ^ 1, &prompt, G, 2), PrefixProbe::Miss));

        let s = tree.stats();
        assert_eq!((s.hits, s.partial_hits, s.misses), (1, 1, 2));
        assert_eq!(s.bytes_deduped, (4 * pool.page_deploy_bytes()) as u64);
        assert_eq!(tree.peek(seed, &prompt, G, 0), PrefixPeek::Full);
        assert_eq!(tree.peek(seed, &p2, G, 2), PrefixPeek::Partial(8));
        assert_eq!(tree.stats().hits, s.hits, "peek must not count");

        tree.clear();
        assert!(tree.is_empty());
        assert_eq!(pool.leased(), 0, "clear releases every pinned page");
    }

    #[test]
    fn partial_walk_cap_keeps_the_last_token_recomputable() {
        // a full-length walk cap still refuses to match the WHOLE prompt
        assert_eq!(RadixTree::partial_walk_groups(8, 8, 4), 1);
        assert_eq!(RadixTree::partial_walk_groups(8, 12, 4), 2);
        assert_eq!(RadixTree::partial_walk_groups(4, 12, 4), 1);
        assert_eq!(RadixTree::partial_walk_groups(0, 12, 4), 0);
        assert_eq!(RadixTree::partial_walk_groups(8, 0, 4), 0);
        assert_eq!(RadixTree::partial_walk_groups(8, 8, 0), 0);
    }

    #[test]
    fn interior_nodes_survive_until_every_dependent_sheds() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 3u64;
        // two prompts share group 1, diverge in group 2
        let a: Vec<i32> = vec![0, 1, 2, 3, 10, 11, 12, 13];
        let b: Vec<i32> = vec![0, 1, 2, 3, 20, 21, 22, 23];
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        assert_eq!((tree.len(), tree.node_count(), tree.pages_pinned()), (2, 3, 3));
        tree.audit().unwrap();

        // LRU erosion: tail A (oldest), then leaf 2a, then tail B, then
        // leaf 2b, then the shared root — which must survive every shed
        // while ANY descendant (tail or child node) still pins it.
        assert!(tree.shed_lru());
        assert_eq!((tree.len(), tree.node_count()), (1, 3));
        assert!(tree.shed_lru());
        assert_eq!((tree.len(), tree.node_count()), (1, 2));
        assert!(tree.shed_lru());
        assert_eq!((tree.len(), tree.node_count()), (0, 2));
        assert!(tree.shed_lru());
        assert_eq!((tree.len(), tree.node_count()), (0, 1));
        assert!(tree.shed_lru());
        assert!(tree.is_empty());
        assert!(!tree.shed_lru(), "nothing left to shed");
        assert_eq!(tree.stats().evictions, 5);
        assert_eq!(pool.leased(), 0);
        tree.audit().unwrap();
    }

    #[test]
    fn touch_path_protects_a_chain_from_lru() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 5u64;
        let a: Vec<i32> = (0..8).collect();
        let b: Vec<i32> = (100..108).collect();
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        let key_a = prompt_chain_key(seed, &a, G);
        let key_b = prompt_chain_key(seed, &b, G);
        // A registered first (older), but an admission touch makes its
        // whole claim newest — pressure shedding must evict B instead.
        tree.touch_path(seed, &a, G, 0);
        assert!(tree.shed_lru());
        assert!(tree.contains(key_a) && !tree.contains(key_b));
    }

    #[test]
    fn register_refuses_duplicates_plan_conflicts_and_over_cap_payloads() {
        let pool = pool(None);
        let mut tree = RadixTree::new(2, pool.page_deploy_bytes());
        let seed = 7u64;
        let prompt: Vec<i32> = (0..8).collect();
        assert!(tree.register(seed, payload(&pool, prompt.clone(), 8)));
        // duplicate: refused, recency refreshed, nothing counted as new
        assert!(!tree.register(seed, payload(&pool, prompt.clone(), 8)));
        assert_eq!(tree.stats().insertions, 1);
        // conflicting channel plan on the shared path: refused outright
        let mut conflicting = payload(&pool, vec![0, 1, 2, 3, 50, 51, 52, 53], 8);
        conflicting.plans = vec![vec![(0..D as i32).rev().collect()]];
        assert!(!tree.register(seed, conflicting));
        assert_eq!(tree.stats().plan_conflicts, 1);
        assert_eq!((tree.len(), tree.node_count()), (1, 2));
        // a payload that can never fit the page cap is rejected, not shed for
        let big = payload(&pool, (0..12).collect(), 12);
        assert!(!tree.register(seed ^ 9, big));
        assert_eq!(tree.stats().rejected, 1);
        tree.audit().unwrap();
    }

    #[test]
    fn page_pressure_sheds_cold_chains_to_admit_new_ones() {
        let pool = pool(None);
        let mut tree = RadixTree::new(2, pool.page_deploy_bytes());
        let seed = 13u64;
        let a: Vec<i32> = (0..8).collect();
        let b: Vec<i32> = (50..58).collect();
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        // B fits only by fully evicting A's tail + 2 nodes
        assert!(!tree.contains(prompt_chain_key(seed, &a, G)));
        assert!(tree.contains(prompt_chain_key(seed, &b, G)));
        assert_eq!((tree.len(), tree.node_count(), tree.pages_pinned()), (1, 2, 2));
        assert_eq!(tree.stats().evictions, 3);
        assert_eq!(pool.leased(), 2);
        tree.audit().unwrap();
    }

    #[test]
    fn entry_cap_bounds_residual_only_tails() {
        let pool = pool(None);
        let mut tree = RadixTree::new(0, pool.page_deploy_bytes());
        for i in 0..(PREFIX_MAX_ENTRIES + 5) {
            let tokens = vec![i as i32, -1, -2]; // t < G: qt = 0, zero pages
            assert!(tree.register(21, payload(&pool, tokens, 0)));
        }
        assert_eq!(tree.len(), PREFIX_MAX_ENTRIES);
        assert_eq!(tree.stats().evictions, 5);
        assert_eq!(tree.node_count(), 0);
        tree.audit().unwrap();
    }

    #[test]
    fn discard_corrupt_cascades_private_nodes_but_spares_shared_ones() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 17u64;
        let a: Vec<i32> = vec![0, 1, 2, 3, 10, 11, 12, 13];
        let b: Vec<i32> = vec![0, 1, 2, 3, 20, 21, 22, 23];
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        let key_a = prompt_chain_key(seed, &a, G);
        assert!(tree.discard_corrupt(key_a));
        // A's leaf went with its tail; the shared root serves B and stays
        assert_eq!((tree.len(), tree.node_count(), tree.pages_pinned()), (1, 2, 2));
        let s = tree.stats();
        assert_eq!((s.collisions, s.misses), (1, 1));
        assert!(!tree.discard_corrupt(key_a), "already gone");
        tree.audit().unwrap();
    }

    #[test]
    fn shed_page_quarantines_the_whole_subtree() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 19u64;
        let a: Vec<i32> = (0..12).collect();
        assert!(tree.register(seed, payload(&pool, a.clone(), 12)));
        let mut ids = Vec::new();
        tree.for_each_page(&mut |p| ids.push(p.id()));
        assert_eq!(ids.len(), 3);
        // the canonical walk is depth order: ids[0] is the root's page, so
        // quarantining it condemns every descendant and the anchored tail
        assert_eq!(tree.shed_page(ids[0]), 4);
        assert!(tree.is_empty());
        assert_eq!(tree.pages_pinned(), 0);
        assert_eq!(pool.leased(), 0);
        assert_eq!(tree.shed_page(ids[0]), 0, "idempotent once gone");
        tree.audit().unwrap();
    }

    /// Serialize `tree`, then rebuild it through `resolve` built over
    /// freshly leased stand-in pages (the server normally reloads page
    /// payloads itself — the tree codec only tracks identity).
    fn roundtrip(tree: &RadixTree, pool: &KvPool, poison: &[u32]) -> (RadixTree, usize) {
        let mut ids = Vec::new();
        tree.for_each_page(&mut |p| ids.push(p.id()));
        let mut buf = Vec::new();
        let mut w = SnapWriter::new(&mut buf).unwrap();
        tree.write_snap(&mut w, &mut |id| {
            ids.iter().position(|&i| i == id).expect("page known") as u32
        })
        .unwrap();
        w.finish().unwrap();
        let stand_ins: Vec<SharedLease> = ids.iter().map(|_| shared_page(pool)).collect();
        let mut r = SnapReader::new(&buf[..]).unwrap();
        let mut restored = RadixTree::new(1024, pool.page_deploy_bytes());
        let dropped = restored
            .read_snap(&mut r, &mut |serial| {
                if poison.contains(&serial) {
                    None
                } else {
                    Some(stand_ins[serial as usize].clone())
                }
            })
            .unwrap();
        r.finish().unwrap();
        (restored, dropped)
    }

    #[test]
    fn snapshot_roundtrip_preserves_structure_counters_and_probes() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 23u64;
        let a: Vec<i32> = vec![0, 1, 2, 3, 10, 11, 12, 13, -5, -6];
        let b: Vec<i32> = vec![0, 1, 2, 3, 20, 21, 22, 23];
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        let _ = tree.lookup(seed, &a, G, 0); // bump some counters
        let _ = tree.lookup(seed, &[9; 8], G, 2);

        let (mut restored, dropped) = roundtrip(&tree, &pool, &[]);
        assert_eq!(dropped, 0);
        restored.audit().unwrap();
        assert_eq!(restored.len(), tree.len());
        assert_eq!(restored.node_count(), tree.node_count());
        assert_eq!(restored.pages_pinned(), tree.pages_pinned());
        let (s0, s1) = (tree.stats(), restored.stats());
        assert_eq!(
            (s0.hits, s0.partial_hits, s0.misses, s0.insertions, s0.bytes_deduped),
            (s1.hits, s1.partial_hits, s1.misses, s1.insertions, s1.bytes_deduped)
        );
        assert_eq!(s0.sidecar_bytes, s1.sidecar_bytes, "sidecar charge restores exactly");
        // the restored tree answers the same probes, sidecar intact
        match restored.lookup(seed, &a, G, 0) {
            PrefixProbe::Full(m) => {
                assert_eq!((m.t, m.qt), (10, 8));
                assert_eq!(m.last_logits(), &[1.0, -2.0]);
                assert_eq!(m.res_k[0][0].len(), 2 * D);
            }
            _ => panic!("expected full hit after restore"),
        }
        // a second registration under the restored tree keeps extending it
        let c: Vec<i32> = vec![0, 1, 2, 3, 30, 31, 32, 33];
        assert!(restored.register(seed, payload(&pool, c, 8)));
        restored.audit().unwrap();
    }

    #[test]
    fn snapshot_restore_drops_poisoned_subtrees_whole() {
        let pool = pool(None);
        let mut tree = RadixTree::new(1024, pool.page_deploy_bytes());
        let seed = 29u64;
        let a: Vec<i32> = vec![0, 1, 2, 3, 10, 11, 12, 13];
        let b: Vec<i32> = vec![0, 1, 2, 3, 20, 21, 22, 23];
        assert!(tree.register(seed, payload(&pool, a.clone(), 8)));
        assert!(tree.register(seed, payload(&pool, b.clone(), 8)));
        // serial 0 is the shared root's page (canonical depth order): a
        // failed checksum there orphans EVERYTHING — both leaves, both tails
        let (restored, dropped) = roundtrip(&tree, &pool, &[0]);
        assert_eq!(dropped, 5);
        assert!(restored.is_empty());
        restored.audit().unwrap();
        let s = restored.stats();
        assert_eq!(s.evictions, tree.stats().evictions + 5);
        // the two dropped tails read back as collision+miss, like discard_corrupt
        assert_eq!(s.misses, tree.stats().misses + 2);
    }
}
