//! Shared paged KV storage: fixed-size, group-aligned pages leased from a
//! `KvPool`.
//!
//! # Why pages
//!
//! The pre-pool layout allocated every tier buffer at full window capacity
//! `C` per (layer, kv-head) per request, so a 10-token request cost as much
//! memory (and as much admission budget) as a 4096-token one. Pages make a
//! request's footprint proportional to what it actually holds: storage is
//! leased one quantization group at a time and returned the moment it is
//! evicted or the request retires, and the scheduler admits on current pool
//! occupancy instead of the worst case.
//!
//! # Page layout
//!
//! One [`Page`] stores **one quantization group of G tokens for one
//! (layer, kv-head)** across every tier buffer of the Fig. 4 layout:
//!
//! ```text
//! f32 arena: [ k16: G*n16 | k4s: n4 | k4z: n4 | k2s: n2 | k2z: n2
//!            | vs: G*d/gv | vz: G*d/gv ]          (v_bits < 16)
//!            [ k16: G*n16 | ... | vfull: G*d ]    (v_bits == 16)
//! u8  arena: [ k4p: G*n4/2 | k2p: G*n2/4 | vp: G*d*v_bits/8 ]
//! ```
//!
//! The per-group scales/zeros live *inside* the page (a group is exactly
//! one scale block), so evicting a group-aligned window block is a page-
//! table splice — no byte shifting, no scale re-indexing. Offsets are
//! derived per [`TierSpec`] by [`PageLayout`]; the same alignment
//! invariants as `quant::packing::packed_len` apply (`n4 % 2 == 0`,
//! `n2 % 4 == 0`, value rows fill whole bytes), so every region is
//! byte-exact and rows are indexed as `ti * row_bytes` within the page.
//!
//! A pool's arenas are sized to the **largest** layout it must serve
//! ([`KvPool::for_specs`]), so heterogeneous decode variants (mixed-
//! precision tenants, layer-wise specs like kvtuner) share one free list
//! with zero fragmentation; smaller specs use arena prefixes.
//!
//! # Leasing discipline
//!
//! [`KvPool::lease`] pops a recycled page (zeroed — no cross-request data
//! leakage) or grows the pool when unbounded; [`PageLease`] returns the
//! page on `Drop`, so eviction, cancellation, admission errors, and request
//! retirement all free storage without an explicit release call — leaks are
//! structurally impossible (`tests/paged_cache.rs` asserts
//! `pool.leased() == 0` after drains). Bounded pools (the serving
//! configuration) are pre-warmed so steady-state leasing never touches the
//! allocator.

use std::cell::RefCell;
use std::ops::Range;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::quant::packing;
use crate::quant::window::TierSpec;

/// Pages `tokens` group-aligned tokens occupy across `n_layers ×
/// n_kv_heads` heads — one page per quantization group per head. The
/// single source of the pages-per-token derivation shared by leasing
/// (`RequestCache::load_prefill`), flush sizing (`pages_per_flush`,
/// `due_flush_pages`), and admission (`Engine::prefill_pages_for`, the
/// server's reserve watermark) — these MUST agree or the scheduler admits
/// on counts that no longer match what the cache leases.
pub fn pages_for_tokens(tokens: usize, group: usize, n_layers: usize, n_kv_heads: usize) -> usize {
    (tokens / group) * n_layers * n_kv_heads
}

/// Raw storage for one page: an f32 arena (BF16-tier columns, scales,
/// zeros, full-precision values) and a byte arena (packed u4/u2 codes).
#[derive(Clone, Debug)]
pub struct Page {
    pub f: Vec<f32>,
    pub b: Vec<u8>,
}

/// Per-spec offsets into a page's arenas (see the module docs for the
/// region order). Pure arithmetic over `TierSpec` — two caches with the
/// same spec always agree on the layout.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageLayout {
    pub spec: TierSpec,
    /// Tokens per page (= key scale-group size G).
    pub g: usize,
    pub d: usize,
    /// Value-side channel group (G clamped to d).
    pub gv: usize,
    o_k4s: usize,
    o_k4z: usize,
    o_k2s: usize,
    o_k2z: usize,
    o_vs: usize,
    o_vz: usize,
    o_vfull: usize,
    /// Total f32 elements this layout occupies.
    pub f_len: usize,
    o_k2p: usize,
    o_vp: usize,
    /// Total bytes this layout occupies.
    pub b_len: usize,
}

/// Immutable view of one page under a layout: every tier region as an
/// exactly-sized slice (empty when the tier is absent). Construction is
/// pure slicing — no allocation, safe for the zero-alloc decode hot path.
pub struct GroupView<'a> {
    pub k16: &'a [f32],
    pub k4p: &'a [u8],
    pub k4s: &'a [f32],
    pub k4z: &'a [f32],
    pub k2p: &'a [u8],
    pub k2s: &'a [f32],
    pub k2z: &'a [f32],
    pub vp: &'a [u8],
    pub vs: &'a [f32],
    pub vz: &'a [f32],
    pub vfull: &'a [f32],
}

impl PageLayout {
    pub fn new(spec: TierSpec, d: usize, group: usize) -> PageLayout {
        // Same alignment invariants as HeadState / packing::packed_len:
        // misaligned tier widths would corrupt the adjacent token's row.
        debug_assert!(spec.n4 % 2 == 0, "u4 tier width {} must be even", spec.n4);
        debug_assert!(spec.n2 % 4 == 0, "u2 tier width {} must be a multiple of 4", spec.n2);
        debug_assert!(
            spec.v_bits == 16 || d % (8 / spec.v_bits) == 0,
            "value rows of {d} channels at {}-bit do not fill whole bytes",
            spec.v_bits
        );
        let g = group;
        let gv = group.min(d);
        let mut f = g * spec.n16; // k16 at offset 0
        let o_k4s = f;
        f += spec.n4;
        let o_k4z = f;
        f += spec.n4;
        let o_k2s = f;
        f += spec.n2;
        let o_k2z = f;
        f += spec.n2;
        let (o_vs, o_vz, o_vfull);
        if spec.v_bits == 16 {
            o_vs = f;
            o_vz = f;
            o_vfull = f;
            f += g * d;
        } else {
            o_vs = f;
            f += g * d / gv;
            o_vz = f;
            f += g * d / gv;
            o_vfull = f;
        }
        let mut b = packing::packed_len(g * spec.n4, 4); // k4p at offset 0
        let o_k2p = b;
        b += packing::packed_len(g * spec.n2, 2);
        let o_vp = b;
        if spec.v_bits != 16 {
            b += packing::packed_len(g * d, spec.v_bits);
        }
        PageLayout {
            spec,
            g,
            d,
            gv,
            o_k4s,
            o_k4z,
            o_k2s,
            o_k2z,
            o_vs,
            o_vz,
            o_vfull,
            f_len: f,
            o_k2p,
            o_vp,
            b_len: b,
        }
    }

    // --- f32 arena regions -------------------------------------------
    pub fn k16r(&self) -> Range<usize> {
        0..self.g * self.spec.n16
    }
    pub fn k4sr(&self) -> Range<usize> {
        self.o_k4s..self.o_k4s + self.spec.n4
    }
    pub fn k4zr(&self) -> Range<usize> {
        self.o_k4z..self.o_k4z + self.spec.n4
    }
    pub fn k2sr(&self) -> Range<usize> {
        self.o_k2s..self.o_k2s + self.spec.n2
    }
    pub fn k2zr(&self) -> Range<usize> {
        self.o_k2z..self.o_k2z + self.spec.n2
    }
    pub fn vsr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { 0 } else { self.g * self.d / self.gv };
        self.o_vs..self.o_vs + n
    }
    pub fn vzr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { 0 } else { self.g * self.d / self.gv };
        self.o_vz..self.o_vz + n
    }
    pub fn vfullr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { self.g * self.d } else { 0 };
        self.o_vfull..self.o_vfull + n
    }

    // --- byte arena regions ------------------------------------------
    pub fn k4pr(&self) -> Range<usize> {
        0..packing::packed_len(self.g * self.spec.n4, 4)
    }
    pub fn k2pr(&self) -> Range<usize> {
        self.o_k2p..self.o_k2p + packing::packed_len(self.g * self.spec.n2, 2)
    }
    pub fn vpr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 {
            0
        } else {
            packing::packed_len(self.g * self.d, self.spec.v_bits)
        };
        self.o_vp..self.o_vp + n
    }

    /// Every tier region of `page` as exactly-sized slices.
    #[inline]
    pub fn view<'a>(&self, page: &'a Page) -> GroupView<'a> {
        GroupView {
            k16: &page.f[self.k16r()],
            k4p: &page.b[self.k4pr()],
            k4s: &page.f[self.k4sr()],
            k4z: &page.f[self.k4zr()],
            k2p: &page.b[self.k2pr()],
            k2s: &page.f[self.k2sr()],
            k2z: &page.f[self.k2zr()],
            vp: &page.b[self.vpr()],
            vs: &page.f[self.vsr()],
            vz: &page.f[self.vzr()],
            vfull: &page.f[self.vfullr()],
        }
    }

    /// Host bytes one page occupies in the pool arenas (f32 scales etc.).
    pub fn host_bytes(&self) -> usize {
        4 * self.f_len + self.b_len
    }

    /// Deployment-layout bytes of one page (the accountant's byte model:
    /// BF16 outlier tier and scales/zeros at 2 B, packed codes as-is) —
    /// `G × accountant::bytes_per_token`.
    pub fn deploy_bytes(&self) -> usize {
        let s = self.spec;
        let key = 2 * self.g * s.n16
            + self.g * s.n4 / 2
            + self.g * s.n2 / 4
            + 2 * 2 * (s.n4 + s.n2);
        let val = if s.v_bits == 16 {
            2 * self.g * self.d
        } else {
            self.g * self.d * s.v_bits / 8 + 2 * 2 * self.g * self.d / self.gv
        };
        key + val
    }
}

struct PoolInner {
    f_len: usize,
    b_len: usize,
    /// `None` = unbounded (per-request private pools); `Some` = the shared
    /// serving pool, capped at a page budget.
    max_pages: Option<usize>,
    free: Vec<Page>,
    leased: usize,
    high_water: usize,
    lease_failures: u64,
    total_leases: u64,
    /// Deployment bytes charged per leased page (worst layout the pool
    /// serves) — the accountant's unit for occupancy gauges.
    page_deploy_bytes: usize,
}

/// Counter snapshot for metrics/gauges (`coordinator::metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub leased: usize,
    pub free: usize,
    pub max_pages: Option<usize>,
    pub high_water: usize,
    pub lease_failures: u64,
    pub total_leases: u64,
    pub page_host_bytes: usize,
    pub page_deploy_bytes: usize,
}

/// Cheap-to-clone handle to a shared page pool. Single-threaded by design
/// (like the rest of the coordinator): `Rc<RefCell>` internally, so leases
/// and returns are pointer operations on one free list.
#[derive(Clone)]
pub struct KvPool {
    inner: Rc<RefCell<PoolInner>>,
}

impl KvPool {
    fn with_arenas(
        f_len: usize,
        b_len: usize,
        max_pages: Option<usize>,
        page_deploy_bytes: usize,
    ) -> KvPool {
        KvPool {
            inner: Rc::new(RefCell::new(PoolInner {
                f_len,
                b_len,
                max_pages,
                free: Vec::new(),
                leased: 0,
                high_water: 0,
                lease_failures: 0,
                total_leases: 0,
                page_deploy_bytes,
            })),
        }
    }

    /// Pool whose arenas fit every layout in `specs` (a shared pool serves
    /// heterogeneous variants — including layer-wise ones — from one free
    /// list). `max_pages: None` grows on demand; `Some(n)` is a hard cap.
    pub fn for_specs<'s>(
        specs: impl IntoIterator<Item = &'s TierSpec>,
        d: usize,
        group: usize,
        max_pages: Option<usize>,
    ) -> KvPool {
        let mut f_len = 0;
        let mut b_len = 0;
        let mut deploy = 0;
        for &spec in specs {
            let lay = PageLayout::new(spec, d, group);
            f_len = f_len.max(lay.f_len);
            b_len = b_len.max(lay.b_len);
            deploy = deploy.max(lay.deploy_bytes());
        }
        KvPool::with_arenas(f_len, b_len, max_pages, deploy)
    }

    /// Unbounded private pool for one layout (standalone caches, tests,
    /// the reference driver).
    pub fn unbounded_for(layout: &PageLayout) -> KvPool {
        KvPool::with_arenas(layout.f_len, layout.b_len, None, layout.deploy_bytes())
    }

    /// Does `layout` fit in this pool's pages?
    pub fn fits(&self, layout: &PageLayout) -> bool {
        let inner = self.inner.borrow();
        layout.f_len <= inner.f_len && layout.b_len <= inner.b_len
    }

    /// Allocate up to `n` pages into the free list so steady-state leasing
    /// never hits the allocator (bounded pools clamp at their cap).
    pub fn prewarm(&self, n: usize) {
        let mut inner = self.inner.borrow_mut();
        let cap = inner
            .max_pages
            .map(|m| m.saturating_sub(inner.leased + inner.free.len()))
            .unwrap_or(n)
            .min(n);
        let (f_len, b_len) = (inner.f_len, inner.b_len);
        for _ in 0..cap {
            inner.free.push(Page { f: vec![0.0; f_len], b: vec![0; b_len] });
        }
    }

    /// Can `n` more pages be leased right now? Never counts as a failure —
    /// this is the scheduler's parking probe.
    pub fn can_lease(&self, n: usize) -> bool {
        let inner = self.inner.borrow();
        match inner.max_pages {
            Some(max) => inner.leased + n <= max,
            None => true,
        }
    }

    /// Lease one page (zeroed). `Err` when a bounded pool is at its cap —
    /// recorded in the lease-failure counter.
    pub fn lease(&self) -> Result<PageLease> {
        let mut inner = self.inner.borrow_mut();
        if let Some(max) = inner.max_pages {
            if inner.leased >= max {
                inner.lease_failures += 1;
                drop(inner);
                bail!("kv pool exhausted: all {max} pages leased");
            }
        }
        let page = match inner.free.pop() {
            Some(mut p) => {
                // recycled page: scrub so no tier data leaks across requests
                p.f.fill(0.0);
                p.b.fill(0);
                p
            }
            None => Page { f: vec![0.0; inner.f_len], b: vec![0; inner.b_len] },
        };
        inner.leased += 1;
        inner.total_leases += 1;
        inner.high_water = inner.high_water.max(inner.leased);
        drop(inner);
        Ok(PageLease { page: Some(page), pool: Rc::clone(&self.inner) })
    }

    /// Record an externally observed lease failure (e.g. a deferred flush
    /// that never called `lease`).
    pub fn note_lease_failure(&self) {
        self.inner.borrow_mut().lease_failures += 1;
    }

    pub fn leased(&self) -> usize {
        self.inner.borrow().leased
    }

    /// Pages still leasable. Unbounded pools report `usize::MAX`.
    pub fn available(&self) -> usize {
        let inner = self.inner.borrow();
        match inner.max_pages {
            Some(max) => max.saturating_sub(inner.leased),
            None => usize::MAX,
        }
    }

    pub fn max_pages(&self) -> Option<usize> {
        self.inner.borrow().max_pages
    }

    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.borrow();
        PoolStats {
            leased: inner.leased,
            free: inner.free.len(),
            max_pages: inner.max_pages,
            high_water: inner.high_water,
            lease_failures: inner.lease_failures,
            total_leases: inner.total_leases,
            page_host_bytes: 4 * inner.f_len + inner.b_len,
            page_deploy_bytes: inner.page_deploy_bytes,
        }
    }

    /// Deployment bytes one leased page is charged at (worst layout the
    /// pool serves) — `budget_bytes / page_deploy_bytes` sizes the pool.
    pub fn page_deploy_bytes(&self) -> usize {
        self.inner.borrow().page_deploy_bytes
    }
}

/// Exclusive lease on one page; returns it to the pool's free list on drop
/// (eviction, cancellation, error unwinding, request retirement — all the
/// release paths are the one destructor).
pub struct PageLease {
    page: Option<Page>,
    pool: Rc<RefCell<PoolInner>>,
}

impl PageLease {
    #[inline]
    pub fn page(&self) -> &Page {
        self.page.as_ref().expect("page present until drop")
    }

    #[inline]
    pub fn page_mut(&mut self) -> &mut Page {
        self.page.as_mut().expect("page present until drop")
    }
}

impl Drop for PageLease {
    fn drop(&mut self) {
        let mut inner = self.pool.borrow_mut();
        inner.leased -= 1;
        if let Some(page) = self.page.take() {
            inner.free.push(page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixspec() -> TierSpec {
        TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }
    }

    #[test]
    fn layout_regions_are_disjoint_and_exhaustive() {
        for spec in [
            mixspec(),
            TierSpec { n16: 0, n4: 32, n2: 0, v_bits: 4 },
            TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 },
            TierSpec { n16: 0, n4: 0, n2: 32, v_bits: 2 },
        ] {
            let lay = PageLayout::new(spec, 32, 32);
            let mut covered_f = vec![false; lay.f_len];
            for r in [lay.k16r(), lay.k4sr(), lay.k4zr(), lay.k2sr(), lay.k2zr(), lay.vsr(), lay.vzr(), lay.vfullr()] {
                for i in r {
                    assert!(!covered_f[i], "{spec:?}: f32 overlap at {i}");
                    covered_f[i] = true;
                }
            }
            assert!(covered_f.iter().all(|&c| c), "{spec:?}: f32 gap");
            let mut covered_b = vec![false; lay.b_len];
            for r in [lay.k4pr(), lay.k2pr(), lay.vpr()] {
                for i in r {
                    assert!(!covered_b[i], "{spec:?}: byte overlap at {i}");
                    covered_b[i] = true;
                }
            }
            assert!(covered_b.iter().all(|&c| c), "{spec:?}: byte gap");
        }
    }

    #[test]
    fn deploy_bytes_matches_accountant_per_token_model() {
        let d = 32;
        let g = 32;
        for spec in [mixspec(), TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }] {
            let lay = PageLayout::new(spec, d, g);
            let per_tok = crate::kvcache::accountant::bytes_per_token(&spec, d, g);
            assert!(
                ((lay.deploy_bytes() as f64) - per_tok * g as f64).abs() < 1e-9,
                "{spec:?}: {} vs {}",
                lay.deploy_bytes(),
                per_tok * g as f64
            );
        }
    }

    #[test]
    fn bounded_pool_caps_and_recycles() {
        let lay = PageLayout::new(mixspec(), 32, 32);
        let pool = KvPool::for_specs([&mixspec()], 32, 32, Some(2));
        assert!(pool.fits(&lay));
        pool.prewarm(10); // clamps to cap
        let a = pool.lease().unwrap();
        let b = pool.lease().unwrap();
        assert_eq!(pool.leased(), 2);
        assert_eq!(pool.available(), 0);
        assert!(!pool.can_lease(1));
        assert!(pool.lease().is_err());
        assert_eq!(pool.stats().lease_failures, 1);
        drop(a);
        assert_eq!(pool.available(), 1);
        let c = pool.lease().unwrap();
        assert!(c.page().f.iter().all(|&x| x == 0.0), "recycled page must be scrubbed");
        drop(b);
        drop(c);
        assert_eq!(pool.leased(), 0);
        assert_eq!(pool.stats().high_water, 2);
        assert_eq!(pool.stats().total_leases, 3);
    }

    #[test]
    fn unbounded_pool_grows_and_reclaims() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let leases: Vec<_> = (0..5).map(|_| pool.lease().unwrap()).collect();
        assert_eq!(pool.leased(), 5);
        assert_eq!(pool.available(), usize::MAX);
        drop(leases);
        assert_eq!(pool.leased(), 0);
        assert_eq!(pool.stats().free, 5);
    }

    #[test]
    fn shared_pool_sized_for_largest_spec() {
        let bf16 = TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 };
        let pool = KvPool::for_specs([&mixspec(), &bf16], 32, 32, None);
        assert!(pool.fits(&PageLayout::new(bf16, 32, 32)));
        assert!(pool.fits(&PageLayout::new(mixspec(), 32, 32)));
        // page charged at the worst (bf16) deployment cost
        assert_eq!(
            pool.page_deploy_bytes(),
            PageLayout::new(bf16, 32, 32).deploy_bytes()
        );
    }

    #[test]
    fn lease_writes_are_isolated_per_page() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let mut a = pool.lease().unwrap();
        let mut b = pool.lease().unwrap();
        a.page_mut().f[0] = 1.0;
        b.page_mut().f[0] = 2.0;
        assert_eq!(a.page().f[0], 1.0);
        assert_eq!(b.page().f[0], 2.0);
    }
}
