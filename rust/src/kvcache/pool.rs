//! Shared paged KV storage: fixed-size, group-aligned pages leased from a
//! `KvPool`.
//!
//! # Why pages
//!
//! The pre-pool layout allocated every tier buffer at full window capacity
//! `C` per (layer, kv-head) per request, so a 10-token request cost as much
//! memory (and as much admission budget) as a 4096-token one. Pages make a
//! request's footprint proportional to what it actually holds: storage is
//! leased one quantization group at a time and returned the moment it is
//! evicted or the request retires, and the scheduler admits on current pool
//! occupancy instead of the worst case.
//!
//! # Page layout
//!
//! One [`Page`] stores **one quantization group of G tokens for one
//! (layer, kv-head)** across every tier buffer of the Fig. 4 layout:
//!
//! ```text
//! f32 arena: [ k16: G*n16 | k4s: n4 | k4z: n4 | k2s: n2 | k2z: n2
//!            | vs: G*d/gv | vz: G*d/gv ]          (v_bits < 16)
//!            [ k16: G*n16 | ... | vfull: G*d ]    (v_bits == 16)
//! u8  arena: [ k4p: G*n4/2 | k2p: G*n2/4 | vp: G*d*v_bits/8 ]
//! ```
//!
//! The per-group scales/zeros live *inside* the page (a group is exactly
//! one scale block), so evicting a group-aligned window block is a page-
//! table splice — no byte shifting, no scale re-indexing. Offsets are
//! derived per [`TierSpec`] by [`PageLayout`]; the same alignment
//! invariants as `quant::packing::packed_len` apply (`n4 % 2 == 0`,
//! `n2 % 4 == 0`, value rows fill whole bytes), so every region is
//! byte-exact and rows are indexed as `ti * row_bytes` within the page.
//!
//! A pool's arenas are sized to the **largest** layout it must serve
//! ([`KvPool::for_specs`]), so heterogeneous decode variants (mixed-
//! precision tenants, layer-wise specs like kvtuner) share one free list
//! with zero fragmentation; smaller specs use arena prefixes.
//!
//! # Leasing discipline
//!
//! [`KvPool::lease`] pops a recycled page (zeroed — no cross-request data
//! leakage) or grows the pool when unbounded; [`PageLease`] returns the
//! page on `Drop`, so eviction, cancellation, admission errors, and request
//! retirement all free storage without an explicit release call — leaks are
//! structurally impossible (`tests/paged_cache.rs` asserts
//! `pool.leased() == 0` after drains). Bounded pools (the serving
//! configuration) are pre-warmed so steady-state leasing never touches the
//! allocator.
//!
//! # Cross-request prefix sharing
//!
//! Once a page has been flushed it is never written again (the residual
//! buffers all mutation; later flushes lease *new* pages) — which makes a
//! prompt's quantized window safe to share across requests. [`SharedLease`]
//! is the refcounted form of a lease: `clone` bumps the count, `drop`
//! decrements it, and the page returns to the pool only when the last
//! holder drops. The content-addressed registry of such shared prompt
//! windows is [`crate::kvcache::radix::RadixTree`]: a group-aligned radix
//! tree over prompt chunks whose node keys are the intermediate links of
//! the rolling hash chain ([`prompt_chain_links`]) scoped to the
//! quantization identity ([`prefix_seed`]), so a probe is an O(chunks)
//! hash walk with a token-compare verify per node (the collision backstop —
//! a 64-bit link match can never serve another prompt's pages), never a
//! scan. Each node pins one reference per page of its G-token span
//! (retention for future tenants, LRU-shed from the leaves under a page cap
//! or pool pressure); a full-prompt tail additionally carries the small
//! per-request state a consumer needs to skip the prefill entirely. N
//! requests over one prompt therefore pay ~1× its quantized bytes and zero
//! (full hit) or tail-only (partial hit) prefill compute; the pool's
//! `leased` counter counts every shared page exactly once, which is what
//! makes the scheduler's occupancy admission charge shared pages once too.

use std::collections::{HashMap, HashSet};
use std::ops::Range;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, Result};

use crate::quant::packing;
use crate::quant::window::TierSpec;
use crate::util::faults::{FaultInjector, FaultSite};

/// Pages `tokens` group-aligned tokens occupy across `n_layers ×
/// n_kv_heads` heads — one page per quantization group per head. The
/// single source of the pages-per-token derivation shared by leasing
/// (`RequestCache::load_prefill`), flush sizing (`pages_per_flush`,
/// `due_flush_pages`), and admission (`Engine::prefill_pages_for`, the
/// server's reserve watermark) — these MUST agree or the scheduler admits
/// on counts that no longer match what the cache leases.
pub fn pages_for_tokens(tokens: usize, group: usize, n_layers: usize, n_kv_heads: usize) -> usize {
    (tokens / group) * n_layers * n_kv_heads
}

/// Raw storage for one page: an f32 arena (BF16-tier columns, scales,
/// zeros, full-precision values) and a byte arena (packed u4/u2 codes).
#[derive(Clone, Debug)]
pub struct Page {
    pub f: Vec<f32>,
    pub b: Vec<u8>,
}

impl Page {
    /// Stable identity of this page's storage: the heap address of its f32
    /// arena (falling back to the byte arena for f32-less layouts). The
    /// buffers never reallocate after construction — pages are fixed-size —
    /// so the id survives moves of the `Page` value itself (into a
    /// `SharedLease`, through the free list) and is unique among live
    /// allocations. Keys the pool's per-page checksum registry and the
    /// quarantine set.
    #[inline]
    pub fn id(&self) -> usize {
        if self.f.is_empty() {
            self.b.as_ptr() as usize
        } else {
            self.f.as_ptr() as usize
        }
    }
}

/// Per-spec offsets into a page's arenas (see the module docs for the
/// region order). Pure arithmetic over `TierSpec` — two caches with the
/// same spec always agree on the layout.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageLayout {
    pub spec: TierSpec,
    /// Tokens per page (= key scale-group size G).
    pub g: usize,
    pub d: usize,
    /// Value-side channel group (G clamped to d).
    pub gv: usize,
    o_k4s: usize,
    o_k4z: usize,
    o_k2s: usize,
    o_k2z: usize,
    o_vs: usize,
    o_vz: usize,
    o_vfull: usize,
    /// Total f32 elements this layout occupies.
    pub f_len: usize,
    o_k2p: usize,
    o_vp: usize,
    /// Total bytes this layout occupies.
    pub b_len: usize,
}

/// Immutable view of one page under a layout: every tier region as an
/// exactly-sized slice (empty when the tier is absent). Construction is
/// pure slicing — no allocation, safe for the zero-alloc decode hot path.
pub struct GroupView<'a> {
    pub k16: &'a [f32],
    pub k4p: &'a [u8],
    pub k4s: &'a [f32],
    pub k4z: &'a [f32],
    pub k2p: &'a [u8],
    pub k2s: &'a [f32],
    pub k2z: &'a [f32],
    pub vp: &'a [u8],
    pub vs: &'a [f32],
    pub vz: &'a [f32],
    pub vfull: &'a [f32],
}

impl PageLayout {
    pub fn new(spec: TierSpec, d: usize, group: usize) -> PageLayout {
        // Same alignment invariants as HeadState / packing::packed_len:
        // misaligned tier widths would corrupt the adjacent token's row.
        debug_assert!(spec.n4 % 2 == 0, "u4 tier width {} must be even", spec.n4);
        debug_assert!(spec.n2 % 4 == 0, "u2 tier width {} must be a multiple of 4", spec.n2);
        debug_assert!(
            spec.v_bits == 16 || d % (8 / spec.v_bits) == 0,
            "value rows of {d} channels at {}-bit do not fill whole bytes",
            spec.v_bits
        );
        let g = group;
        let gv = group.min(d);
        let mut f = g * spec.n16; // k16 at offset 0
        let o_k4s = f;
        f += spec.n4;
        let o_k4z = f;
        f += spec.n4;
        let o_k2s = f;
        f += spec.n2;
        let o_k2z = f;
        f += spec.n2;
        let (o_vs, o_vz, o_vfull);
        if spec.v_bits == 16 {
            o_vs = f;
            o_vz = f;
            o_vfull = f;
            f += g * d;
        } else {
            o_vs = f;
            f += g * d / gv;
            o_vz = f;
            f += g * d / gv;
            o_vfull = f;
        }
        let mut b = packing::packed_len(g * spec.n4, 4); // k4p at offset 0
        let o_k2p = b;
        b += packing::packed_len(g * spec.n2, 2);
        let o_vp = b;
        if spec.v_bits != 16 {
            b += packing::packed_len(g * d, spec.v_bits);
        }
        PageLayout {
            spec,
            g,
            d,
            gv,
            o_k4s,
            o_k4z,
            o_k2s,
            o_k2z,
            o_vs,
            o_vz,
            o_vfull,
            f_len: f,
            o_k2p,
            o_vp,
            b_len: b,
        }
    }

    // --- f32 arena regions -------------------------------------------
    pub fn k16r(&self) -> Range<usize> {
        0..self.g * self.spec.n16
    }
    pub fn k4sr(&self) -> Range<usize> {
        self.o_k4s..self.o_k4s + self.spec.n4
    }
    pub fn k4zr(&self) -> Range<usize> {
        self.o_k4z..self.o_k4z + self.spec.n4
    }
    pub fn k2sr(&self) -> Range<usize> {
        self.o_k2s..self.o_k2s + self.spec.n2
    }
    pub fn k2zr(&self) -> Range<usize> {
        self.o_k2z..self.o_k2z + self.spec.n2
    }
    pub fn vsr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { 0 } else { self.g * self.d / self.gv };
        self.o_vs..self.o_vs + n
    }
    pub fn vzr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { 0 } else { self.g * self.d / self.gv };
        self.o_vz..self.o_vz + n
    }
    pub fn vfullr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 { self.g * self.d } else { 0 };
        self.o_vfull..self.o_vfull + n
    }

    // --- byte arena regions ------------------------------------------
    pub fn k4pr(&self) -> Range<usize> {
        0..packing::packed_len(self.g * self.spec.n4, 4)
    }
    pub fn k2pr(&self) -> Range<usize> {
        self.o_k2p..self.o_k2p + packing::packed_len(self.g * self.spec.n2, 2)
    }
    pub fn vpr(&self) -> Range<usize> {
        let n = if self.spec.v_bits == 16 {
            0
        } else {
            packing::packed_len(self.g * self.d, self.spec.v_bits)
        };
        self.o_vp..self.o_vp + n
    }

    /// Every tier region of `page` as exactly-sized slices.
    #[inline]
    pub fn view<'a>(&self, page: &'a Page) -> GroupView<'a> {
        GroupView {
            k16: &page.f[self.k16r()],
            k4p: &page.b[self.k4pr()],
            k4s: &page.f[self.k4sr()],
            k4z: &page.f[self.k4zr()],
            k2p: &page.b[self.k2pr()],
            k2s: &page.f[self.k2sr()],
            k2z: &page.f[self.k2zr()],
            vp: &page.b[self.vpr()],
            vs: &page.f[self.vsr()],
            vz: &page.f[self.vzr()],
            vfull: &page.f[self.vfullr()],
        }
    }

    /// Host bytes one page occupies in the pool arenas (f32 scales etc.).
    pub fn host_bytes(&self) -> usize {
        4 * self.f_len + self.b_len
    }

    /// Deployment-layout bytes of one page (the accountant's byte model:
    /// BF16 outlier tier and scales/zeros at 2 B, packed codes as-is) —
    /// `G × accountant::bytes_per_token`.
    pub fn deploy_bytes(&self) -> usize {
        let s = self.spec;
        let key = 2 * self.g * s.n16
            + self.g * s.n4 / 2
            + self.g * s.n2 / 4
            + 2 * 2 * (s.n4 + s.n2);
        let val = if s.v_bits == 16 {
            2 * self.g * self.d
        } else {
            self.g * self.d * s.v_bits / 8 + 2 * 2 * self.g * self.d / self.gv
        };
        key + val
    }
}

struct PoolInner {
    f_len: usize,
    b_len: usize,
    /// `None` = unbounded (per-request private pools); `Some` = the shared
    /// serving pool, capped at a page budget.
    max_pages: Option<usize>,
    free: Vec<Page>,
    leased: usize,
    high_water: usize,
    lease_failures: u64,
    total_leases: u64,
    /// Deployment bytes charged per leased page (worst layout the pool
    /// serves) — the accountant's unit for occupancy gauges.
    page_deploy_bytes: usize,
    /// Deterministic fault injection (chaos testing): when installed,
    /// `lease_keyed` may be denied transiently at the plan's `LeaseDenial`
    /// rate. `None` (the default) costs nothing on the lease path.
    faults: Option<Arc<FaultInjector>>,
    /// Integrity registry: `Page::id` → FNV-1a checksum, recorded when a
    /// page's flush seals it (`seal_page`) and removed when the lease
    /// returns. A sealed page is immutable (see the sharing docs), so a
    /// later `verify_page` mismatch is bit rot / corruption, not staleness.
    checksums: HashMap<usize, u64>,
    /// Page ids condemned by a failed verify: their buffers are discarded
    /// (never recycled) when the owning lease drops, and `check_invariants`
    /// asserts no holder still references them.
    quarantined: HashSet<usize>,
    /// Lifetime count of quarantined pages (metrics gauge).
    quarantined_total: u64,
}

/// Counter snapshot for metrics/gauges (`coordinator::metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub leased: usize,
    pub free: usize,
    pub max_pages: Option<usize>,
    pub high_water: usize,
    pub lease_failures: u64,
    pub total_leases: u64,
    pub page_host_bytes: usize,
    pub page_deploy_bytes: usize,
    /// Pages currently covered by a seal checksum.
    pub sealed: usize,
    /// Lifetime count of pages quarantined by failed integrity checks.
    pub quarantined_total: u64,
}

/// Cheap-to-clone handle to a shared page pool. Thread-safe
/// (`Arc<Mutex>` internally) so worker-pool decode/prefill jobs can
/// lease and return pages concurrently: every critical section is a
/// pointer operation on one free list plus counter bumps — no user code
/// ever runs under the lock, so contention is bounded by page traffic,
/// not compute. Lock recovery ignores poisoning deliberately: the pool's
/// invariants are maintained before any statement that could panic, and
/// `PageLease::drop` must be able to return pages while a worker job is
/// unwinding (the worker pool catches and re-raises job panics).
#[derive(Clone)]
pub struct KvPool {
    inner: Arc<Mutex<PoolInner>>,
}

fn lock_inner(inner: &Mutex<PoolInner>) -> MutexGuard<'_, PoolInner> {
    inner.lock().unwrap_or_else(|e| e.into_inner())
}

impl KvPool {
    fn with_arenas(
        f_len: usize,
        b_len: usize,
        max_pages: Option<usize>,
        page_deploy_bytes: usize,
    ) -> KvPool {
        KvPool {
            inner: Arc::new(Mutex::new(PoolInner {
                f_len,
                b_len,
                max_pages,
                free: Vec::new(),
                leased: 0,
                high_water: 0,
                lease_failures: 0,
                total_leases: 0,
                page_deploy_bytes,
                faults: None,
                checksums: HashMap::new(),
                quarantined: HashSet::new(),
                quarantined_total: 0,
            })),
        }
    }

    /// Pool whose arenas fit every layout in `specs` (a shared pool serves
    /// heterogeneous variants — including layer-wise ones — from one free
    /// list). `max_pages: None` grows on demand; `Some(n)` is a hard cap.
    pub fn for_specs<'s>(
        specs: impl IntoIterator<Item = &'s TierSpec>,
        d: usize,
        group: usize,
        max_pages: Option<usize>,
    ) -> KvPool {
        let mut f_len = 0;
        let mut b_len = 0;
        let mut deploy = 0;
        for &spec in specs {
            let lay = PageLayout::new(spec, d, group);
            f_len = f_len.max(lay.f_len);
            b_len = b_len.max(lay.b_len);
            deploy = deploy.max(lay.deploy_bytes());
        }
        KvPool::with_arenas(f_len, b_len, max_pages, deploy)
    }

    /// Unbounded private pool for one layout (standalone caches, tests,
    /// the reference driver).
    pub fn unbounded_for(layout: &PageLayout) -> KvPool {
        KvPool::with_arenas(layout.f_len, layout.b_len, None, layout.deploy_bytes())
    }

    /// Does `layout` fit in this pool's pages?
    pub fn fits(&self, layout: &PageLayout) -> bool {
        let inner = lock_inner(&self.inner);
        layout.f_len <= inner.f_len && layout.b_len <= inner.b_len
    }

    /// Allocate up to `n` pages into the free list so steady-state leasing
    /// never hits the allocator (bounded pools clamp at their cap).
    pub fn prewarm(&self, n: usize) {
        let mut inner = lock_inner(&self.inner);
        let cap = inner
            .max_pages
            .map(|m| m.saturating_sub(inner.leased + inner.free.len()))
            .unwrap_or(n)
            .min(n);
        let (f_len, b_len) = (inner.f_len, inner.b_len);
        for _ in 0..cap {
            inner.free.push(Page { f: vec![0.0; f_len], b: vec![0; b_len] });
        }
    }

    /// Can `n` more pages be leased right now? Never counts as a failure —
    /// this is the scheduler's parking probe. With workers > 1 the answer
    /// is only schedule-invariant when the caller holds a reservation (the
    /// router's parking pass guarantees the sum of unparked slots' needs
    /// fits before the parallel phase dispatches).
    pub fn can_lease(&self, n: usize) -> bool {
        let inner = lock_inner(&self.inner);
        match inner.max_pages {
            Some(max) => inner.leased + n <= max,
            None => true,
        }
    }

    /// Install a deterministic fault injector: `lease_keyed` then fails
    /// transiently at the plan's `LeaseDenial` rate (counted in
    /// `lease_failures`, like a real cap denial). All clones of this pool
    /// share the injector — it lives in the shared inner state.
    pub fn set_fault_injector(&self, faults: Arc<FaultInjector>) {
        lock_inner(&self.inner).faults = Some(faults);
    }

    /// Lease one page under a deterministic draw key (see
    /// [`crate::util::faults::draw_key`]): an installed fault injector may
    /// deny the lease transiently at the plan's `LeaseDenial` rate. The
    /// key, not call order, decides the outcome — worker threads leasing
    /// in any interleaving reproduce the same fault schedule. This is the
    /// production path (`HeadState::store_key_window` supplies the key);
    /// [`KvPool::lease`] is the fault-free form for standalone caches and
    /// tests.
    pub fn lease_keyed(&self, key: u64) -> Result<PageLease> {
        let faults = lock_inner(&self.inner).faults.clone();
        if let Some(f) = faults {
            if f.should_fail(FaultSite::LeaseDenial, key) {
                lock_inner(&self.inner).lease_failures += 1;
                bail!("injected transient fault: kv pool lease denied");
            }
        }
        self.lease()
    }

    /// Lease one page (zeroed). `Err` when a bounded pool is at its cap —
    /// recorded in the lease-failure counter. Never consults the fault
    /// injector (that is [`KvPool::lease_keyed`]'s job).
    pub fn lease(&self) -> Result<PageLease> {
        let mut inner = lock_inner(&self.inner);
        if let Some(max) = inner.max_pages {
            if inner.leased >= max {
                inner.lease_failures += 1;
                drop(inner);
                bail!("kv pool exhausted: all {max} pages leased");
            }
        }
        let page = match inner.free.pop() {
            Some(mut p) => {
                // recycled page: scrub so no tier data leaks across requests
                p.f.fill(0.0);
                p.b.fill(0);
                p
            }
            None => Page { f: vec![0.0; inner.f_len], b: vec![0; inner.b_len] },
        };
        inner.leased += 1;
        inner.total_leases += 1;
        inner.high_water = inner.high_water.max(inner.leased);
        drop(inner);
        Ok(PageLease { page: Some(page), pool: Arc::clone(&self.inner) })
    }

    /// Record an externally observed lease failure (e.g. a deferred flush
    /// that never called `lease`).
    pub fn note_lease_failure(&self) {
        lock_inner(&self.inner).lease_failures += 1;
    }

    pub fn leased(&self) -> usize {
        lock_inner(&self.inner).leased
    }

    /// Pages still leasable. Unbounded pools report `usize::MAX`.
    pub fn available(&self) -> usize {
        let inner = lock_inner(&self.inner);
        match inner.max_pages {
            Some(max) => max.saturating_sub(inner.leased),
            None => usize::MAX,
        }
    }

    pub fn max_pages(&self) -> Option<usize> {
        lock_inner(&self.inner).max_pages
    }

    pub fn stats(&self) -> PoolStats {
        let inner = lock_inner(&self.inner);
        PoolStats {
            leased: inner.leased,
            free: inner.free.len(),
            max_pages: inner.max_pages,
            high_water: inner.high_water,
            lease_failures: inner.lease_failures,
            total_leases: inner.total_leases,
            page_host_bytes: 4 * inner.f_len + inner.b_len,
            page_deploy_bytes: inner.page_deploy_bytes,
            sealed: inner.checksums.len(),
            quarantined_total: inner.quarantined_total,
        }
    }

    /// Deployment bytes one leased page is charged at (worst layout the
    /// pool serves) — `budget_bytes / page_deploy_bytes` sizes the pool.
    pub fn page_deploy_bytes(&self) -> usize {
        lock_inner(&self.inner).page_deploy_bytes
    }

    /// Arena dimensions `(f_len, b_len)` — snapshot geometry guards compare
    /// these before attempting to reload any page payloads.
    pub fn arena_dims(&self) -> (usize, usize) {
        let inner = lock_inner(&self.inner);
        (inner.f_len, inner.b_len)
    }

    // --- page integrity (seal / verify / quarantine) -----------------

    /// Record `page`'s content checksum in the integrity registry. Called
    /// once a flush completes a page (`RequestCache::quantize_into` — after
    /// which the page is immutable, see the sharing docs), and again on
    /// restore after a reloaded payload verifies. Re-sealing overwrites,
    /// so the registry always reflects the final flushed content.
    pub fn seal_page(&self, page: &Page) {
        let h = crate::util::snapshot::page_checksum(&page.f, &page.b);
        lock_inner(&self.inner).checksums.insert(page.id(), h);
    }

    /// Re-derive `page`'s checksum and compare it against the seal record.
    /// `false` means corruption (content drifted since seal) — or a page
    /// that was never sealed, which the fourth `check_invariants` audit
    /// rules out for every live page at a tick boundary.
    pub fn verify_page(&self, page: &Page) -> bool {
        let h = crate::util::snapshot::page_checksum(&page.f, &page.b);
        lock_inner(&self.inner).checksums.get(&page.id()) == Some(&h)
    }

    /// The seal checksum recorded for a page id, if any.
    pub fn sealed_checksum(&self, id: usize) -> Option<u64> {
        lock_inner(&self.inner).checksums.get(&id).copied()
    }

    /// Condemn a page id after a failed integrity check: its seal record is
    /// dropped and, when the owning lease returns, the buffers are
    /// discarded instead of recycled (capacity self-heals — `lease`
    /// allocates fresh storage once the free list runs dry). The *caller*
    /// retires the owning request / sheds the owning prefix entry; the pool
    /// only guarantees the bytes never serve again.
    pub fn quarantine_page(&self, id: usize) {
        let mut inner = lock_inner(&self.inner);
        inner.checksums.remove(&id);
        if inner.quarantined.insert(id) {
            inner.quarantined_total += 1;
        }
    }

    pub fn is_quarantined(&self, id: usize) -> bool {
        lock_inner(&self.inner).quarantined.contains(&id)
    }

    /// Every page id currently covered by a seal record (sorted, so audits
    /// get a deterministic view).
    pub fn checksum_ids(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = lock_inner(&self.inner).checksums.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Lifetime count of quarantined pages.
    pub fn quarantined_total(&self) -> u64 {
        lock_inner(&self.inner).quarantined_total
    }

    /// Overwrite the lifetime counters from a snapshot so a restored
    /// server's gauges continue the interrupted run's series (the live
    /// `leased` count is rebuilt by the restore's actual leases, never
    /// overwritten).
    pub fn restore_counters(&self, high_water: usize, lease_failures: u64, total_leases: u64) {
        let mut inner = lock_inner(&self.inner);
        inner.high_water = inner.high_water.max(high_water);
        inner.lease_failures = lease_failures;
        inner.total_leases = total_leases;
    }
}

/// Exclusive lease on one page; returns it to the pool's free list on drop
/// (eviction, cancellation, error unwinding, request retirement — all the
/// release paths are the one destructor).
pub struct PageLease {
    page: Option<Page>,
    pool: Arc<Mutex<PoolInner>>,
}

impl PageLease {
    // The `expect`s below are true invariant checks, not per-request error
    // paths: `page` is only `None` inside `Drop::drop`, which no accessor
    // can race (a lease is exclusively owned; `&mut self` guards the
    // mutation) — a trip here is a use-after-drop bug.
    #[inline]
    pub fn page(&self) -> &Page {
        self.page.as_ref().expect("page present until drop")
    }

    #[inline]
    pub fn page_mut(&mut self) -> &mut Page {
        self.page.as_mut().expect("page present until drop")
    }
}

impl Drop for PageLease {
    fn drop(&mut self) {
        // poison-recovering lock: this destructor must return the page even
        // while a worker job is unwinding (the pool re-raises the panic on
        // the coordinator after the drain barrier)
        let mut inner = lock_inner(&self.pool);
        inner.leased -= 1;
        if let Some(page) = self.page.take() {
            inner.checksums.remove(&page.id());
            if inner.quarantined.remove(&page.id()) {
                // condemned storage is discarded, never recycled; capacity
                // self-heals because `lease` allocates fresh buffers once
                // the free list runs dry
            } else {
                inner.free.push(page);
            }
        }
    }
}

/// Refcounted, **read-only** lease on a flushed page: `clone` bumps the
/// count, `drop` decrements it, and the underlying [`PageLease`] (and with
/// it the page) returns to the pool when the count reaches zero. The pool's
/// `leased` counter sees the page exactly once no matter how many requests
/// hold it — that single charge is the memory-dedup win of prefix sharing.
#[derive(Clone)]
pub struct SharedLease {
    inner: Arc<PageLease>,
}

impl SharedLease {
    pub fn new(lease: PageLease) -> SharedLease {
        SharedLease { inner: Arc::new(lease) }
    }

    #[inline]
    pub fn page(&self) -> &Page {
        self.inner.page()
    }

    /// Current holders (page tables + the prefix tree's pin).
    pub fn refs(&self) -> usize {
        Arc::strong_count(&self.inner)
    }

    /// Stable identity of the underlying pool lease — the same physical
    /// page yields the same id from every holder. The pool's `leased`
    /// counter charges each id exactly once, so invariant audits
    /// (`Server::check_invariants`) dedup holders by this id to reconcile
    /// against `KvPool::leased`.
    pub fn page_id(&self) -> usize {
        Arc::as_ptr(&self.inner) as usize
    }
}

/// One page-table slot: either an exclusive (writable) lease or a shared
/// read-only prefix page. The seam contract of copy-on-write sharing lives
/// here: reads stream through either variant identically, while a write to
/// a shared page is a hard bug (shared pages are immutable after their
/// flush — divergence past the shared region leases *new* private pages,
/// it never touches old ones).
pub enum PageRef {
    Private(PageLease),
    Shared(SharedLease),
}

impl PageRef {
    #[inline]
    pub fn page(&self) -> &Page {
        match self {
            PageRef::Private(l) => l.page(),
            PageRef::Shared(s) => s.page(),
        }
    }

    /// Writable access — **private pages only**. Panicking here (instead of
    /// silently corrupting every co-tenant of the page) is deliberate: no
    /// correct store path ever addresses a page below the shared seam.
    #[inline]
    pub fn page_mut(&mut self) -> &mut Page {
        match self {
            PageRef::Private(l) => l.page_mut(),
            PageRef::Shared(_) => {
                panic!("copy-on-write violation: shared prefix pages are read-only after flush")
            }
        }
    }

    pub fn is_shared(&self) -> bool {
        matches!(self, PageRef::Shared(_))
    }

    /// Convert this slot to the shared form (idempotent), handing back one
    /// additional [`SharedLease`] reference for the prefix tree.
    pub fn into_shared(self) -> (PageRef, SharedLease) {
        match self {
            PageRef::Private(l) => {
                let s = SharedLease::new(l);
                (PageRef::Shared(s.clone()), s)
            }
            PageRef::Shared(s) => {
                let extra = s.clone();
                (PageRef::Shared(s), extra)
            }
        }
    }
}

// --- content-addressed prefix keys --------------------------------------

pub(crate) fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Namespace half of a prefix key: everything that shapes what a prompt
/// quantizes *into*. Two requests may share pages only when the method (tier
/// shapes, ordering, rotation, clipping), the residual split (`r_limit`),
/// the group size, the window capacity, and the model cache geometry all
/// agree — the chain walk then only has to compare tokens.
pub fn prefix_seed(
    method_name: &str,
    r_limit: usize,
    group: usize,
    capacity: usize,
    n_layers: usize,
    n_kv_heads: usize,
    d_head: usize,
) -> u64 {
    let mut h = fnv1a(0xcbf2_9ce4_8422_2325, method_name.as_bytes());
    for v in [r_limit, group, capacity, n_layers, n_kv_heads, d_head] {
        h = fnv1a(h, &(v as u64).to_le_bytes());
    }
    h
}

/// Group-aligned rolling hash chain over a prompt: one link per G-token
/// group plus a final link for the unaligned tail, so the walk is
/// O(chunks) and a shared prefix of two prompts shares a hash prefix. The
/// full-prompt key (the last link) is what radix-tree *tails* (the
/// full-prefill sidecar state) are registered under; the intermediate
/// links ([`prompt_chain_links`]) key the tree's interior nodes, one per
/// full G-token group, so a probe descends the shared hash prefix and a
/// partial hit adopts exactly the matched groups. Bit-exact sharing still
/// requires the entire prompt to match — partial hits run in frozen-plan
/// mode with a bounded, measured extra quantization error (see the
/// `kvcache::cache` docs for the seam contract).
///
/// ```
/// use mixkvq::kvcache::pool::{prefix_seed, prompt_chain_key};
/// let seed = prefix_seed("mixkvq-mix30", 128, 32, 512, 4, 2, 32);
/// let a = prompt_chain_key(seed, &[1, 2, 3, 4], 2);
/// assert_eq!(a, prompt_chain_key(seed, &[1, 2, 3, 4], 2));
/// assert_ne!(a, prompt_chain_key(seed, &[1, 2, 3, 5], 2)); // content-addressed
/// assert_ne!(a, prompt_chain_key(seed, &[1, 2, 3], 2)); // length-sensitive
/// ```
pub fn prompt_chain_key(seed: u64, tokens: &[i32], group: usize) -> u64 {
    let mut h = seed;
    for chunk in tokens.chunks(group.max(1)) {
        let mut link = fnv1a(h, &(chunk.len() as u64).to_le_bytes());
        for &t in chunk {
            link = fnv1a(link, &t.to_le_bytes());
        }
        h = link;
    }
    h
}

/// Every intermediate link of the [`prompt_chain_key`] chain, one per
/// (possibly partial) chunk, in walk order: `links[i]` keys the prefix
/// `tokens[..(i+1)*group]` (clamped to `tokens.len()`). These are the radix
/// tree's node addresses — a probe descends link by link, and two prompts
/// sharing a group-aligned prefix share the corresponding link prefix.
pub fn prompt_chain_links(seed: u64, tokens: &[i32], group: usize) -> Vec<u64> {
    let mut h = seed;
    let mut links = Vec::with_capacity(tokens.len().div_ceil(group.max(1)));
    for chunk in tokens.chunks(group.max(1)) {
        let mut link = fnv1a(h, &(chunk.len() as u64).to_le_bytes());
        for &t in chunk {
            link = fnv1a(link, &t.to_le_bytes());
        }
        h = link;
        links.push(link);
    }
    links
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixspec() -> TierSpec {
        TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 }
    }

    #[test]
    fn layout_regions_are_disjoint_and_exhaustive() {
        for spec in [
            mixspec(),
            TierSpec { n16: 0, n4: 32, n2: 0, v_bits: 4 },
            TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 },
            TierSpec { n16: 0, n4: 0, n2: 32, v_bits: 2 },
        ] {
            let lay = PageLayout::new(spec, 32, 32);
            let mut covered_f = vec![false; lay.f_len];
            for r in [lay.k16r(), lay.k4sr(), lay.k4zr(), lay.k2sr(), lay.k2zr(), lay.vsr(), lay.vzr(), lay.vfullr()] {
                for i in r {
                    assert!(!covered_f[i], "{spec:?}: f32 overlap at {i}");
                    covered_f[i] = true;
                }
            }
            assert!(covered_f.iter().all(|&c| c), "{spec:?}: f32 gap");
            let mut covered_b = vec![false; lay.b_len];
            for r in [lay.k4pr(), lay.k2pr(), lay.vpr()] {
                for i in r {
                    assert!(!covered_b[i], "{spec:?}: byte overlap at {i}");
                    covered_b[i] = true;
                }
            }
            assert!(covered_b.iter().all(|&c| c), "{spec:?}: byte gap");
        }
    }

    #[test]
    fn deploy_bytes_matches_accountant_per_token_model() {
        let d = 32;
        let g = 32;
        for spec in [mixspec(), TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 }] {
            let lay = PageLayout::new(spec, d, g);
            let per_tok = crate::kvcache::accountant::bytes_per_token(&spec, d, g);
            assert!(
                ((lay.deploy_bytes() as f64) - per_tok * g as f64).abs() < 1e-9,
                "{spec:?}: {} vs {}",
                lay.deploy_bytes(),
                per_tok * g as f64
            );
        }
    }

    #[test]
    fn bounded_pool_caps_and_recycles() {
        let lay = PageLayout::new(mixspec(), 32, 32);
        let pool = KvPool::for_specs([&mixspec()], 32, 32, Some(2));
        assert!(pool.fits(&lay));
        pool.prewarm(10); // clamps to cap
        let a = pool.lease().unwrap();
        let b = pool.lease().unwrap();
        assert_eq!(pool.leased(), 2);
        assert_eq!(pool.available(), 0);
        assert!(!pool.can_lease(1));
        assert!(pool.lease().is_err());
        assert_eq!(pool.stats().lease_failures, 1);
        drop(a);
        assert_eq!(pool.available(), 1);
        let c = pool.lease().unwrap();
        assert!(c.page().f.iter().all(|&x| x == 0.0), "recycled page must be scrubbed");
        drop(b);
        drop(c);
        assert_eq!(pool.leased(), 0);
        assert_eq!(pool.stats().high_water, 2);
        assert_eq!(pool.stats().total_leases, 3);
    }

    #[test]
    fn pool_handles_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<KvPool>();
        assert_send_sync::<PageLease>();
        assert_send_sync::<SharedLease>();
        assert_send_sync::<PageRef>();
    }

    #[test]
    fn keyed_lease_faults_are_schedule_independent() {
        use crate::util::faults::{draw_key, FaultPlan};
        let make = || {
            let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
            pool.set_fault_injector(FaultInjector::shared(FaultPlan::uniform(13, 0.5)));
            pool
        };
        let keys: Vec<u64> = (0..64).map(|s| draw_key(5, s)).collect();
        let fwd: Vec<bool> = {
            let pool = make();
            keys.iter().map(|&k| pool.lease_keyed(k).is_err()).collect()
        };
        let rev: Vec<bool> = {
            let pool = make();
            let mut r: Vec<bool> =
                keys.iter().rev().map(|&k| pool.lease_keyed(k).is_err()).collect();
            r.reverse();
            r
        };
        assert_eq!(fwd, rev, "lease-denial schedule must not depend on draw order");
        assert!(fwd.iter().any(|&x| x), "50% over 64 draws must deny at least once");
        // denied leases count as failures; unkeyed lease never draws
        let pool = make();
        let denied = keys.iter().filter(|&&k| pool.lease_keyed(k).is_err()).count();
        assert_eq!(pool.stats().lease_failures, denied as u64);
        assert!(pool.lease().is_ok());
    }

    #[test]
    fn concurrent_lease_and_return_balances_books() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, Some(64));
        pool.prewarm(64);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = pool.clone();
                std::thread::spawn(move || {
                    for _ in 0..200 {
                        let a = p.lease().unwrap();
                        let b = p.lease().unwrap();
                        drop(a);
                        drop(b);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.leased(), 0);
        assert_eq!(pool.stats().total_leases, 4 * 400);
        assert!(pool.stats().high_water <= 8);
    }

    #[test]
    fn unbounded_pool_grows_and_reclaims() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let leases: Vec<_> = (0..5).map(|_| pool.lease().unwrap()).collect();
        assert_eq!(pool.leased(), 5);
        assert_eq!(pool.available(), usize::MAX);
        drop(leases);
        assert_eq!(pool.leased(), 0);
        assert_eq!(pool.stats().free, 5);
    }

    #[test]
    fn shared_pool_sized_for_largest_spec() {
        let bf16 = TierSpec { n16: 32, n4: 0, n2: 0, v_bits: 16 };
        let pool = KvPool::for_specs([&mixspec(), &bf16], 32, 32, None);
        assert!(pool.fits(&PageLayout::new(bf16, 32, 32)));
        assert!(pool.fits(&PageLayout::new(mixspec(), 32, 32)));
        // page charged at the worst (bf16) deployment cost
        assert_eq!(
            pool.page_deploy_bytes(),
            PageLayout::new(bf16, 32, 32).deploy_bytes()
        );
    }

    #[test]
    fn lease_writes_are_isolated_per_page() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let mut a = pool.lease().unwrap();
        let mut b = pool.lease().unwrap();
        a.page_mut().f[0] = 1.0;
        b.page_mut().f[0] = 2.0;
        assert_eq!(a.page().f[0], 1.0);
        assert_eq!(b.page().f[0], 2.0);
    }

    #[test]
    fn shared_lease_frees_page_only_at_zero_refs() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, Some(2));
        pool.prewarm(2);
        let mut lease = pool.lease().unwrap();
        lease.page_mut().f[0] = 7.0;
        let a = SharedLease::new(lease);
        let b = a.clone();
        let c = b.clone();
        assert_eq!(a.refs(), 3);
        // a shared page is leased ONCE no matter how many holders
        assert_eq!(pool.leased(), 1);
        assert_eq!(a.page().f[0], 7.0);
        drop(a);
        drop(c);
        assert_eq!(b.refs(), 1);
        assert_eq!(pool.leased(), 1, "page must stay leased while any ref lives");
        drop(b);
        assert_eq!(pool.leased(), 0, "last ref must return the page");
    }

    #[test]
    fn page_ref_share_is_idempotent_and_reads_both_variants() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let mut lease = pool.lease().unwrap();
        lease.page_mut().b[0] = 9;
        let p = PageRef::Private(lease);
        assert!(!p.is_shared());
        let (p, extra) = p.into_shared();
        assert!(p.is_shared());
        assert_eq!(extra.refs(), 2);
        let (p, extra2) = p.into_shared();
        assert_eq!(p.page().b[0], 9);
        assert_eq!(extra2.refs(), 3);
        drop((extra, extra2));
        drop(p);
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write violation")]
    fn writing_a_shared_page_panics() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, None);
        let (mut p, _extra) = PageRef::Private(pool.lease().unwrap()).into_shared();
        let _ = p.page_mut();
    }

    #[test]
    fn chain_key_is_group_aligned_and_prefix_sensitive() {
        let seed = prefix_seed("mixkvq-mix30", 128, 32, 512, 4, 2, 32);
        let other_seed = prefix_seed("kivi-kv2", 128, 32, 512, 4, 2, 32);
        assert_ne!(seed, other_seed, "method identity must scope the key");
        let toks: Vec<i32> = (0..100).collect();
        let k1 = prompt_chain_key(seed, &toks, 32);
        assert_eq!(k1, prompt_chain_key(seed, &toks, 32));
        // any token change, anywhere, changes the key
        let mut t2 = toks.clone();
        t2[0] = 999;
        assert_ne!(k1, prompt_chain_key(seed, &t2, 32));
        let mut t3 = toks.clone();
        t3[99] = 999;
        assert_ne!(k1, prompt_chain_key(seed, &t3, 32));
        // length-sensitive: a strict prefix keys differently
        assert_ne!(k1, prompt_chain_key(seed, &toks[..96], 32));
        assert_ne!(k1, prompt_chain_key(other_seed, &toks, 32));
        // the link chain exposes every group-aligned prefix key: the last
        // link IS the full key, and link i keys tokens[..(i+1)*32]
        let links = prompt_chain_links(seed, &toks, 32);
        assert_eq!(links.len(), 4); // 3 full groups + unaligned tail
        assert_eq!(*links.last().unwrap(), k1);
        assert_eq!(links[2], prompt_chain_key(seed, &toks[..96], 32));
        // shared-prefix prompts share a link prefix, then diverge
        let links3 = prompt_chain_links(seed, &t3, 32);
        assert_eq!(links[..3], links3[..3]);
        assert_ne!(links[3], links3[3]);
    }

    #[test]
    fn seal_verify_quarantine_lifecycle() {
        let pool = KvPool::for_specs([&mixspec()], 32, 32, Some(2));
        pool.prewarm(2);
        let mut a = pool.lease().unwrap();
        a.page_mut().f[0] = 3.5;
        a.page_mut().b[1] = 9;
        let id = a.page().id();
        // unsealed pages never verify
        assert!(!pool.verify_page(a.page()));
        pool.seal_page(a.page());
        assert_eq!(pool.stats().sealed, 1);
        assert!(pool.verify_page(a.page()));
        assert_eq!(
            pool.sealed_checksum(id),
            Some(crate::util::snapshot::page_checksum(&a.page().f, &a.page().b))
        );
        // corruption after seal fails verification
        a.page_mut().b[1] ^= 0x40;
        assert!(!pool.verify_page(a.page()));
        pool.quarantine_page(id);
        assert!(pool.is_quarantined(id));
        assert_eq!(pool.quarantined_total(), 1);
        assert_eq!(pool.stats().sealed, 0, "quarantine drops the seal record");
        // the condemned page's buffers are discarded on drop, not recycled
        drop(a);
        assert!(!pool.is_quarantined(id), "quarantine entry clears with the lease");
        assert_eq!(pool.stats().free, 1, "only the prewarmed sibling remains");
        // capacity self-heals: both pages still leasable
        let b = pool.lease().unwrap();
        let c = pool.lease().unwrap();
        assert_eq!(pool.leased(), 2);
        drop((b, c));
        // a healthy page's seal record clears on drop too
        let d = pool.lease().unwrap();
        pool.seal_page(d.page());
        assert_eq!(pool.stats().sealed, 1);
        drop(d);
        assert_eq!(pool.stats().sealed, 0);
        assert_eq!(pool.quarantined_total(), 1, "lifetime counter never rewinds");
    }

}
