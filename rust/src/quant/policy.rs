//! Adaptive precision policy: *choosing* a request's [`MethodSpec`] instead
//! of configuring it — the serving-side decision layer the paper's premise
//! implies (precision by difficulty/relevance) and the related work makes
//! explicit (KVTuner's offline layer-sensitivity plans, KVmix's per-layer
//! bit-widths under a memory budget).
//!
//! A [`PrecisionPolicy`] resolves an **admission ladder**: an ordered list
//! of candidate specs, most preferred first, every entry drawn from
//! [`MethodSpec::all`]. The server tries the ladder top-down against the
//! pool's occupancy admission — under pool pressure a new request degrades
//! to a cheaper rung instead of stalling the queue, which turns the
//! existing `KvPool`/scheduler watermark into a memory-vs-accuracy dial.
//! Requests carrying an explicit `MethodSpec` override bypass the policy
//! entirely (see `quant::methods` on who may choose).
//!
//! Costs come from [`SpecCosts`] (worst-case request bytes per spec, from
//! the accountant); quality predictions come from a [`SensitivityProfile`]
//! measured offline by `harness::profiling` and cached as a JSON artifact.

use anyhow::{bail, Context, Result};

use crate::kvcache::accountant::MemoryAccountant;
use crate::model::config::Meta;
use crate::quant::methods::MethodSpec;
use crate::util::json::{num, obj, s, Json};

/// Multiplicative slack on a profile's additive per-layer error sum when
/// quoting a *bound* (cross-layer quantization errors compound, so the sum
/// is a prediction, not a guarantee).
pub const PREDICTED_BOUND_SLACK: f64 = 4.0;
/// Absolute slack (nats of mean NLL) added on top of the multiplicative
/// term, so near-zero predictions still quote a usable bound.
pub const PREDICTED_BOUND_EPS: f64 = 0.25;

/// Worst-case per-request byte cost of every resolvable spec under one
/// `Meta`, sorted most→least expensive (ties keep roster order). The
/// policy's shared cost model: both the `MemorySlo` filter and the
/// degradation ladders walk this table.
#[derive(Clone, Debug)]
pub struct SpecCosts {
    entries: Vec<(MethodSpec, usize)>,
}

impl SpecCosts {
    /// Cost out every spec whose decode variant `meta` knows (unknown
    /// variants are simply not admissible and are dropped).
    pub fn from_meta(meta: &Meta) -> SpecCosts {
        let mut entries: Vec<(MethodSpec, usize)> = MethodSpec::all()
            .into_iter()
            .filter_map(|spec| {
                let v = meta.variant(spec.variant()).ok()?;
                let bytes = MemoryAccountant::worst_case_request_bytes(
                    &meta.model,
                    &meta.cache,
                    &v.layers,
                );
                Some((spec, bytes))
            })
            .collect();
        entries.sort_by(|a, b| b.1.cmp(&a.1)); // stable: ties keep roster order
        SpecCosts { entries }
    }

    /// Worst-case request bytes for `spec` (`None` when its variant is
    /// unknown to the `Meta` this table was built from).
    pub fn cost(&self, spec: MethodSpec) -> Option<usize> {
        self.entries.iter().find(|(s, _)| *s == spec).map(|(_, c)| *c)
    }

    /// `(spec, worst-case bytes)` pairs, most expensive first.
    pub fn iter(&self) -> impl Iterator<Item = (MethodSpec, usize)> + '_ {
        self.entries.iter().copied()
    }

    pub fn most_expensive(&self) -> Option<MethodSpec> {
        self.entries.first().map(|(s, _)| *s)
    }

    pub fn cheapest(&self) -> Option<MethodSpec> {
        self.entries.last().map(|(s, _)| *s)
    }
}

/// Offline sensitivity profile: per-(spec, layer) error deltas on a
/// calibration workload, measured by `harness::profiling::profile` with
/// every *other* layer pinned at bf16 (the KVTuner-style one-layer-at-a-time
/// sweep). Error is the mean-NLL delta vs the all-bf16 baseline, clamped at
/// zero. Serialized as a JSON artifact so the sweep runs once per model.
#[derive(Clone, Debug, Default)]
pub struct SensitivityProfile {
    /// Mean NLL of the all-bf16 baseline on the calibration set.
    pub baseline_nll: f64,
    pub n_layers: usize,
    /// Calibration workload identity (seed recorded for reproducibility).
    pub calib_seed: u64,
    pub entries: Vec<ProfileEntry>,
}

#[derive(Clone, Debug)]
pub struct ProfileEntry {
    pub spec: MethodSpec,
    /// `layer_err[l]` = mean-NLL delta with only layer `l` quantized under
    /// this spec (≥ 0).
    pub layer_err: Vec<f64>,
    /// Worst-case request bytes under this spec (denormalized from the
    /// cost table at profiling time, so the artifact is self-contained).
    pub worst_case_bytes: usize,
}

impl SensitivityProfile {
    fn entry(&self, spec: MethodSpec) -> Option<&ProfileEntry> {
        self.entries.iter().find(|e| e.spec == spec)
    }

    /// Additive per-layer error prediction for serving `spec` on all
    /// layers at once (`None` when the spec was not profiled).
    pub fn predicted_error(&self, spec: MethodSpec) -> Option<f64> {
        self.entry(spec).map(|e| e.layer_err.iter().sum())
    }

    /// The bound the profile is willing to quote for `spec`'s measured
    /// error on the calibration set: the additive prediction with
    /// compounding slack. `harness::profiling` verifies measured error
    /// stays inside this.
    pub fn predicted_bound(&self, spec: MethodSpec) -> Option<f64> {
        self.predicted_error(spec)
            .map(|e| e * PREDICTED_BOUND_SLACK + PREDICTED_BOUND_EPS)
    }

    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                obj(vec![
                    ("spec", s(&e.spec.to_string())),
                    (
                        "layer_err",
                        Json::Arr(e.layer_err.iter().map(|&x| num(x)).collect()),
                    ),
                    ("worst_case_bytes", num(e.worst_case_bytes as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("schema", s("mixkvq-profile-v1")),
            ("baseline_nll", num(self.baseline_nll)),
            ("n_layers", num(self.n_layers as f64)),
            ("calib_seed", num(self.calib_seed as f64)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse a profile artifact. Every failure names the offending field
    /// (`profile field \`x\`: …`) so a hand-edited or version-skewed
    /// `profile.json` is diagnosable from the error alone.
    pub fn from_json(j: &Json) -> Result<SensitivityProfile> {
        let field = |name: &'static str| move || format!("profile field `{name}`");
        let schema = j
            .get("schema")
            .and_then(|v| v.as_str())
            .with_context(field("schema"))?;
        if schema != "mixkvq-profile-v1" {
            bail!(
                "unknown profile schema `{schema}` (this build reads mixkvq-profile-v1 \
                 — regenerate with `mixkvq profile`)"
            );
        }
        let n_layers = j
            .get("n_layers")
            .and_then(|v| v.as_usize())
            .with_context(field("n_layers"))?;
        let mut entries = Vec::new();
        for (i, e) in j
            .get("entries")
            .and_then(|v| v.as_arr())
            .with_context(field("entries"))?
            .iter()
            .enumerate()
        {
            let ctx = |name: &'static str| move || format!("profile entry {i} field `{name}`");
            let name = e.get("spec").and_then(|v| v.as_str()).with_context(ctx("spec"))?;
            let spec: MethodSpec = name
                .parse()
                .map_err(|err: String| anyhow::anyhow!("profile entry {i}: {err}"))?;
            let layer_err: Vec<f64> = e
                .get("layer_err")
                .and_then(|v| v.as_arr())
                .with_context(ctx("layer_err"))?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<_>>()
                .with_context(ctx("layer_err"))?;
            if layer_err.len() != n_layers {
                bail!("profile entry `{name}`: {} layer errors, want {n_layers}", layer_err.len());
            }
            entries.push(ProfileEntry {
                spec,
                layer_err,
                worst_case_bytes: e
                    .get("worst_case_bytes")
                    .and_then(|v| v.as_usize())
                    .with_context(ctx("worst_case_bytes"))?,
            });
        }
        Ok(SensitivityProfile {
            baseline_nll: j
                .get("baseline_nll")
                .and_then(|v| v.as_f64())
                .with_context(field("baseline_nll"))?,
            n_layers,
            calib_seed: j
                .get("calib_seed")
                .and_then(|v| v.as_usize())
                .with_context(field("calib_seed"))? as u64,
            entries,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().print())
            .with_context(|| format!("writing profile {path:?}"))
    }

    pub fn load(path: &std::path::Path) -> Result<SensitivityProfile> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {path:?}"))?;
        Self::from_json(&Json::parse(&src)?)
    }
}

/// Runtime precision policy: how the server resolves a `MethodSpec` for a
/// request that did not pin one itself.
#[derive(Clone, Debug)]
pub enum PrecisionPolicy {
    /// Every unpinned request serves under this one spec (the pre-policy
    /// behavior, as a policy). Single-rung ladder: no degradation.
    Fixed(MethodSpec),
    /// Serve the most expensive spec whose **worst-case** request bytes
    /// fit `budget_bytes`; under pool pressure degrade down the cost
    /// ladder (still inside the budget). An empty ladder — no spec fits —
    /// rejects at submit.
    MemorySlo { budget_bytes: usize },
    /// Serve the profile's lowest-predicted-error spec; the degradation
    /// ladder is the (error, bytes) Pareto frontier, so each rung down is
    /// strictly cheaper (never a lateral move that costs quality for
    /// nothing).
    LayerSensitivity { profile: SensitivityProfile },
}

impl PrecisionPolicy {
    /// The admission ladder: candidate specs most-preferred first. Every
    /// entry is one of [`MethodSpec::all`] with a variant `costs` knows;
    /// an empty ladder means no spec is acceptable and the request must
    /// be rejected. Walking left→right never increases worst-case bytes
    /// (degradation is monotone by construction).
    pub fn candidates(&self, costs: &SpecCosts) -> Vec<MethodSpec> {
        match self {
            PrecisionPolicy::Fixed(spec) => {
                // unknown-variant Fixed pins nothing admissible
                costs.cost(*spec).map(|_| *spec).into_iter().collect()
            }
            PrecisionPolicy::MemorySlo { budget_bytes } => costs
                .iter()
                .filter(|(_, c)| *c <= *budget_bytes)
                .map(|(spec, _)| spec)
                .collect(),
            PrecisionPolicy::LayerSensitivity { profile } => {
                // sort by predicted error (cheaper bytes break ties), then
                // keep the Pareto frontier: each kept rung is strictly
                // cheaper than the previous one
                let mut scored: Vec<(MethodSpec, f64, usize)> = costs
                    .iter()
                    .filter_map(|(spec, c)| {
                        profile.predicted_error(spec).map(|e| (spec, e, c))
                    })
                    .collect();
                scored.sort_by(|a, b| {
                    a.1.partial_cmp(&b.1)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.2.cmp(&b.2))
                });
                let mut ladder = Vec::new();
                let mut min_cost = usize::MAX;
                for (spec, _, c) in scored {
                    if c < min_cost {
                        ladder.push(spec);
                        min_cost = c;
                    }
                }
                ladder
            }
        }
    }

    /// The ladder's top rung — what an unpressured admission serves.
    pub fn resolve(&self, costs: &SpecCosts) -> Option<MethodSpec> {
        self.candidates(costs).into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> SpecCosts {
        SpecCosts::from_meta(&Meta::default_build())
    }

    #[test]
    fn cost_table_covers_all_specs_sorted() {
        let c = costs();
        // default_build knows every variant, so all 17 specs cost out
        assert_eq!(c.iter().count(), MethodSpec::all().len());
        let v: Vec<usize> = c.iter().map(|(_, b)| b).collect();
        assert!(v.windows(2).all(|w| w[0] >= w[1]), "not sorted desc: {v:?}");
        assert_eq!(c.most_expensive(), Some(MethodSpec::Bf16));
        assert!(c.cost(MethodSpec::Bf16).unwrap() > c.cost(c.cheapest().unwrap()).unwrap());
    }

    #[test]
    fn fixed_is_single_rung() {
        let c = costs();
        let p = PrecisionPolicy::Fixed(MethodSpec::KvTuner);
        assert_eq!(p.candidates(&c), vec![MethodSpec::KvTuner]);
        assert_eq!(p.resolve(&c), Some(MethodSpec::KvTuner));
    }

    #[test]
    fn memory_slo_respects_budget_and_degrades_monotone() {
        let c = costs();
        let max = c.cost(MethodSpec::Bf16).unwrap();
        let p = PrecisionPolicy::MemorySlo { budget_bytes: max };
        let ladder = p.candidates(&c);
        assert_eq!(ladder.len(), MethodSpec::all().len());
        let costs_desc: Vec<usize> = ladder.iter().map(|&s| c.cost(s).unwrap()).collect();
        assert!(costs_desc.windows(2).all(|w| w[0] >= w[1]));
        // a budget below the cheapest spec resolves nothing
        let min = c.cost(c.cheapest().unwrap()).unwrap();
        let p = PrecisionPolicy::MemorySlo { budget_bytes: min - 1 };
        assert!(p.resolve(&c).is_none());
    }

    #[test]
    fn sensitivity_ladder_is_pareto_frontier() {
        let c = costs();
        let meta = Meta::default_build();
        // synthetic profile: error inversely ordered with cost (realistic)
        let entries: Vec<ProfileEntry> = c
            .iter()
            .enumerate()
            .map(|(i, (spec, bytes))| ProfileEntry {
                spec,
                layer_err: vec![i as f64 * 0.01; meta.model.n_layers],
                worst_case_bytes: bytes,
            })
            .collect();
        let profile = SensitivityProfile {
            baseline_nll: 1.0,
            n_layers: meta.model.n_layers,
            calib_seed: 0,
            entries,
        };
        let p = PrecisionPolicy::LayerSensitivity { profile };
        let ladder = p.candidates(&c);
        assert!(!ladder.is_empty());
        // best-quality first (here: the most expensive), strictly cheaper
        // down the ladder
        assert_eq!(ladder[0], MethodSpec::Bf16);
        let lc: Vec<usize> = ladder.iter().map(|&s| c.cost(s).unwrap()).collect();
        assert!(lc.windows(2).all(|w| w[0] > w[1]), "{lc:?}");
    }

    #[test]
    fn profile_json_roundtrip() {
        let profile = SensitivityProfile {
            baseline_nll: 3.5,
            n_layers: 2,
            calib_seed: 17,
            entries: vec![ProfileEntry {
                spec: MethodSpec::KvTuner,
                layer_err: vec![0.25, 0.0],
                worst_case_bytes: 12345,
            }],
        };
        let back = SensitivityProfile::from_json(&profile.to_json()).unwrap();
        assert_eq!(back.n_layers, 2);
        assert_eq!(back.calib_seed, 17);
        assert_eq!(back.entries.len(), 1);
        assert_eq!(back.entries[0].spec, MethodSpec::KvTuner);
        assert_eq!(back.entries[0].worst_case_bytes, 12345);
        assert!((back.predicted_error(MethodSpec::KvTuner).unwrap() - 0.25).abs() < 1e-12);
        let bound = back.predicted_bound(MethodSpec::KvTuner).unwrap();
        assert!(bound >= 0.25 * PREDICTED_BOUND_SLACK);
        assert!(back.predicted_error(MethodSpec::Bf16).is_none());
    }

    #[test]
    fn malformed_profiles_error_with_field_names() {
        // wrong schema version names both what it found and what it wants
        let j = Json::parse(r#"{"schema": "mixkvq-profile-v9"}"#).unwrap();
        let e = format!("{:#}", SensitivityProfile::from_json(&j).unwrap_err());
        assert!(e.contains("mixkvq-profile-v9"), "{e}");
        assert!(e.contains("mixkvq-profile-v1"), "{e}");
        // missing field → error names it
        let j = Json::parse(r#"{"schema": "mixkvq-profile-v1"}"#).unwrap();
        let e = format!("{:#}", SensitivityProfile::from_json(&j).unwrap_err());
        assert!(e.contains("n_layers"), "{e}");
        // wrong type deep in an entry → error names entry index and field
        let j = Json::parse(
            r#"{"schema": "mixkvq-profile-v1", "baseline_nll": 1.0, "n_layers": 1,
                "calib_seed": 0,
                "entries": [{"spec": "kvtuner", "layer_err": [0.1],
                             "worst_case_bytes": "lots"}]}"#,
        )
        .unwrap();
        let e = format!("{:#}", SensitivityProfile::from_json(&j).unwrap_err());
        assert!(e.contains("worst_case_bytes"), "{e}");
        assert!(e.contains("a string"), "{e}");
        // truncated file: parse error, never a panic
        let good = SensitivityProfile {
            baseline_nll: 1.0,
            n_layers: 1,
            calib_seed: 0,
            entries: vec![],
        }
        .to_json()
        .print();
        for cut in 0..good.len() - 1 {
            assert!(
                Json::parse(&good[..cut]).is_err(),
                "truncation at {cut} parsed"
            );
        }
    }
}
