//! The paper's core contribution: the query-aware Salience Score (Sec. 4.2).
//!
//! * Importance `I_d = mean_i |Q_{i,d}|` (Eq. 6) — a running accumulator fed
//!   by the `qabs` output of the prefill/decode HLO (App. D.2's "efficient
//!   online saliency estimation"; RoPE is applied before the statistic).
//! * Sensitivity `S_d = (max k_d − min k_d)/(2^B − 1)` (Eq. 7) over the
//!   window being quantized.
//! * Salience `A_d = I_d · S_d` (Eq. 8). Channels with high `A_d` go to the
//!   BF16 tier, then UINT4, then UINT2 — either by thresholds
//!   (τ_BF16, τ_UINT4; paper App. C) or by fixed tier *counts* (the
//!   static-shape form used on the HLO path, DESIGN.md §Hardware-Adaptation).

use crate::quant::asym::qmax;

/// Running per-channel accumulator of |Q| (one per layer × kv-head).
#[derive(Clone, Debug)]
pub struct QueryStats {
    pub sum_abs: Vec<f32>,
    pub count: f32,
}

impl QueryStats {
    pub fn new(d: usize) -> Self {
        QueryStats { sum_abs: vec![0.0; d], count: 0.0 }
    }

    /// Fold in a mean-|Q| observation covering `weight` query positions
    /// (prefill passes weight = prompt length, decode passes 1).
    pub fn update(&mut self, mean_abs_q: &[f32], weight: f32) {
        debug_assert_eq!(mean_abs_q.len(), self.sum_abs.len());
        for (s, &m) in self.sum_abs.iter_mut().zip(mean_abs_q) {
            *s += m * weight;
        }
        self.count += weight;
    }

    /// I_d (Eq. 6). Uniform if no queries observed yet.
    pub fn importance(&self) -> Vec<f32> {
        if self.count == 0.0 {
            return vec![1.0; self.sum_abs.len()];
        }
        self.sum_abs.iter().map(|s| s / self.count).collect()
    }
}

/// S_d (Eq. 7) for a [t, d] row-major key window at reference bit-width `bits`.
pub fn sensitivity(k: &[f32], t: usize, d: usize, bits: usize) -> Vec<f32> {
    assert_eq!(k.len(), t * d);
    let denom = qmax(bits) as f32;
    let mut out = vec![0.0f32; d];
    for ch in 0..d {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for tok in 0..t {
            let x = k[tok * d + ch];
            lo = lo.min(x);
            hi = hi.max(x);
        }
        out[ch] = (hi - lo) / denom;
    }
    out
}

/// A_d = I_d · S_d (Eq. 8).
pub fn salience(importance: &[f32], sensitivity: &[f32]) -> Vec<f32> {
    importance.iter().zip(sensitivity).map(|(i, s)| i * s).collect()
}

/// How each channel is ordered into precision tiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Natural channel order (fixed-precision baselines: KIVI, KVQuant, ...).
    Natural,
    /// Descending S_d only — the "error-only" ablation of Table 6.
    SensitivityOnly,
    /// Descending A_d = I_d · S_d — full MixKVQ.
    Salience,
}

/// Channel permutation for tier assignment: the first `n16` entries of the
/// returned order land in BF16, the next `n4` in UINT4, the rest in UINT2.
pub fn channel_order(ordering: Ordering, importance: &[f32], sens: &[f32]) -> Vec<usize> {
    let d = sens.len();
    let mut idx: Vec<usize> = (0..d).collect();
    match ordering {
        Ordering::Natural => {}
        Ordering::SensitivityOnly => {
            idx.sort_by(|&a, &b| sens[b].partial_cmp(&sens[a]).unwrap());
        }
        Ordering::Salience => {
            let a = salience(importance, sens);
            idx.sort_by(|&x, &y| a[y].partial_cmp(&a[x]).unwrap());
        }
    }
    idx
}

/// Threshold-based tier counts (App. C form): returns (n16, n4) for a
/// salience vector and thresholds (τ_BF16, τ_UINT4).
pub fn threshold_counts(a: &[f32], tau_bf16: f32, tau_u4: f32) -> (usize, usize) {
    let n16 = a.iter().filter(|&&x| x > tau_bf16).count();
    let n4 = a.iter().filter(|&&x| x > tau_u4 && x <= tau_bf16).count();
    (n16, n4)
}

/// Effective key bit-width for tier counts (Eq. 17 restricted to one head).
pub fn effective_key_bits(n16: usize, n4: usize, n2: usize) -> f64 {
    (16 * n16 + 4 * n4 + 2 * n2) as f64 / (n16 + n4 + n2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn importance_is_running_mean() {
        let mut qs = QueryStats::new(2);
        qs.update(&[1.0, 3.0], 2.0); // 2 positions averaging 1.0 / 3.0
        qs.update(&[4.0, 0.0], 1.0);
        let i = qs.importance();
        assert!((i[0] - 2.0).abs() < 1e-6); // (1*2 + 4*1)/3
        assert!((i[1] - 2.0).abs() < 1e-6); // (3*2 + 0*1)/3
    }

    #[test]
    fn sensitivity_matches_range() {
        // channel 0 range 4 => s = 4/3 at 2-bit; channel 1 constant => 0
        let k = vec![0.0, 5.0, 4.0, 5.0, 2.0, 5.0, 1.0, 5.0];
        let s = sensitivity(&k, 4, 2, 2);
        assert!((s[0] - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(s[1], 0.0);
    }

    #[test]
    fn salience_orders_by_product() {
        // high S but tiny I must lose to moderate S with high I — the
        // paper's Fig. 3 argument against scale-only selection.
        let imp = vec![0.01, 1.0, 0.5];
        let sens = vec![10.0, 1.0, 1.0];
        let order = channel_order(Ordering::Salience, &imp, &sens);
        assert_eq!(order[0], 1); // A = [0.1, 1.0, 0.5]
        let order_s = channel_order(Ordering::SensitivityOnly, &imp, &sens);
        assert_eq!(order_s[0], 0);
    }

    #[test]
    fn natural_order_is_identity() {
        let order = channel_order(Ordering::Natural, &[1.0; 5], &[1.0; 5]);
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threshold_monotonicity_property() {
        // raising tau_BF16 never increases the BF16 count (invariant #4).
        let mut rng = Pcg32::seeded(31);
        for _ in 0..100 {
            let a: Vec<f32> = (0..32).map(|_| rng.f32() * 2.0).collect();
            let t1 = rng.f32() * 2.0;
            let t2 = t1 + rng.f32();
            let (n16_lo, _) = threshold_counts(&a, t1, 0.0);
            let (n16_hi, _) = threshold_counts(&a, t2, 0.0);
            assert!(n16_hi <= n16_lo);
        }
    }

    #[test]
    fn effective_bits_examples() {
        assert!((effective_key_bits(2, 2, 28) - 3.0).abs() < 1e-9);
        assert!((effective_key_bits(0, 4, 28) - 2.25).abs() < 1e-9);
        assert!((effective_key_bits(32, 0, 0) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn order_is_a_permutation() {
        let mut rng = Pcg32::seeded(32);
        for _ in 0..50 {
            let imp: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
            let sens: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
            let mut o = channel_order(Ordering::Salience, &imp, &sens);
            o.sort_unstable();
            assert_eq!(o, (0..32).collect::<Vec<_>>());
        }
    }
}
