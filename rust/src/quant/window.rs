//! Quantized key/value windows — the exact byte layout the decode HLO
//! consumes (see python/compile/model.py::decode_input_manifest).
//!
//! A window is `t` tokens for one (layer, kv-head). The kvcache module
//! copies windows into capacity-C device buffers; this module only deals in
//! window-local data.

use crate::quant::asym;
use crate::quant::packing;
use crate::quant::salience::{self, Ordering};

/// Per-layer tier spec: (n16, n4, n2) key channels + value bit-width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierSpec {
    pub n16: usize,
    pub n4: usize,
    pub n2: usize,
    pub v_bits: usize,
}

impl TierSpec {
    pub fn d(&self) -> usize {
        self.n16 + self.n4 + self.n2
    }

    pub fn key_bits(&self) -> f64 {
        salience::effective_key_bits(self.n16, self.n4, self.n2)
    }
}

/// Three-tier quantized key window (rotated space), ABI-ready.
#[derive(Clone, Debug)]
pub struct KeyWindow {
    pub t: usize,
    pub spec: TierSpec,
    /// Channel permutation: tier j holds original channel `order[j]`.
    pub order: Vec<usize>,
    pub k16: Vec<f32>,     // [t, n16]
    pub k4p: Vec<u8>,      // [t, n4/2]
    pub k4s: Vec<f32>,     // [t/G, n4]
    pub k4z: Vec<f32>,
    pub k2p: Vec<u8>,      // [t, n2/4]
    pub k2s: Vec<f32>,     // [t/G, n2]
    pub k2z: Vec<f32>,
}

/// Quantized (or full-precision) value window.
#[derive(Clone, Debug)]
pub struct ValueWindow {
    pub t: usize,
    pub bits: usize,       // 2, 4 or 16
    pub vfull: Vec<f32>,   // [t, d] when bits == 16
    pub vp: Vec<u8>,       // [t, d*bits/8] otherwise
    pub vs: Vec<f32>,      // [t, d/G]
    pub vz: Vec<f32>,
}

/// Options shaping how a key window is quantized (method-dependent).
#[derive(Clone, Copy, Debug)]
pub struct KeyQuantOpts {
    pub clip: f32,          // SKVQ range clipping (1.0 = off)
    pub global_scales: bool, // KVQuant whole-window per-channel scales
    pub group: usize,
}

/// Channel permutation for a window under `ordering` (the per-request tier
/// plan; computed once per request then reused so the decode graph sees a
/// stable `idx` input — DESIGN.md §Hardware-Adaptation).
pub fn plan_order(ordering: Ordering, importance: &[f32], k: &[f32], t: usize, d: usize) -> Vec<usize> {
    let sens = salience::sensitivity(k, t, d, 2);
    salience::channel_order(ordering, importance, &sens)
}

/// Quantize a [t, d] key window (already rotated if the method rotates)
/// under an explicit channel `order` (see [`plan_order`]).
pub fn quantize_key_window(
    k: &[f32],
    t: usize,
    d: usize,
    spec: TierSpec,
    order: &[usize],
    opts: KeyQuantOpts,
) -> KeyWindow {
    assert_eq!(spec.d(), d);
    assert_eq!(k.len(), t * d);
    let order = order.to_vec();

    // Gather permuted columns into a contiguous [t, d] matrix.
    let mut perm = vec![0f32; t * d];
    for tok in 0..t {
        for (j, &src) in order.iter().enumerate() {
            perm[tok * d + j] = k[tok * d + src];
        }
    }
    let col_block = |lo: usize, n: usize| -> Vec<f32> {
        let mut out = vec![0f32; t * n];
        for tok in 0..t {
            out[tok * n..(tok + 1) * n].copy_from_slice(&perm[tok * d + lo..tok * d + lo + n]);
        }
        out
    };

    let k16 = col_block(0, spec.n16);

    let quant_tier = |lo: usize, n: usize, bits: usize| -> (Vec<u8>, Vec<f32>, Vec<f32>) {
        if n == 0 {
            return (Vec::new(), Vec::new(), Vec::new());
        }
        let block = col_block(lo, n);
        let (codes, s, z) = if opts.global_scales {
            asym::quantize_key_channelwise_global(&block, t, n, opts.group, bits)
        } else {
            asym::quantize_key_channelwise(&block, t, n, opts.group, bits, opts.clip)
        };
        let mut packed = Vec::with_capacity(packing::packed_len(t * n, bits));
        for tok in 0..t {
            let row = &codes[tok * n..(tok + 1) * n];
            if bits == 4 {
                packing::pack_u4(row, &mut packed);
            } else {
                packing::pack_u2(row, &mut packed);
            }
        }
        (packed, s, z)
    };

    let (k4p, k4s, k4z) = quant_tier(spec.n16, spec.n4, 4);
    let (k2p, k2s, k2z) = quant_tier(spec.n16 + spec.n4, spec.n2, 2);

    KeyWindow { t, spec, order, k16, k4p, k4s, k4z, k2p, k2s, k2z }
}

/// Quantize a [t, d] value window per-token (Sec. 4.2: "value cache
/// undergoes uniform per-token quantization").
pub fn quantize_value_window(v: &[f32], t: usize, d: usize, bits: usize, group: usize) -> ValueWindow {
    assert_eq!(v.len(), t * d);
    if bits == 16 {
        return ValueWindow {
            t,
            bits,
            vfull: v.to_vec(),
            vp: Vec::new(),
            vs: Vec::new(),
            vz: Vec::new(),
        };
    }
    let (codes, vs, vz) = asym::quantize_value_tokenwise(v, t, d, group, bits);
    let mut vp = Vec::with_capacity(packing::packed_len(t * d, bits));
    for tok in 0..t {
        let row = &codes[tok * d..(tok + 1) * d];
        if bits == 4 {
            packing::pack_u4(row, &mut vp);
        } else {
            packing::pack_u2(row, &mut vp);
        }
    }
    ValueWindow { t, bits, vfull: Vec::new(), vp, vs, vz }
}

/// Dequantize a key window back to the ORIGINAL (pre-permutation) channel
/// order — the reference-path inverse used by model/reference.rs and the
/// error analyses (Figs. 2/6).
pub fn dequantize_key_window(w: &KeyWindow, d: usize, group: usize) -> Vec<f32> {
    let t = w.t;
    let mut perm = vec![0f32; t * d];
    // BF16 tier
    for tok in 0..t {
        for j in 0..w.spec.n16 {
            perm[tok * d + j] = w.k16[tok * w.spec.n16 + j];
        }
    }
    if w.spec.n4 > 0 {
        let mut codes = Vec::with_capacity(t * w.spec.n4);
        packing::unpack_u4(&w.k4p, &mut codes);
        let de = asym::dequantize_key_channelwise(&codes, &w.k4s, &w.k4z, t, w.spec.n4, group);
        for tok in 0..t {
            for j in 0..w.spec.n4 {
                perm[tok * d + w.spec.n16 + j] = de[tok * w.spec.n4 + j];
            }
        }
    }
    if w.spec.n2 > 0 {
        let base = w.spec.n16 + w.spec.n4;
        let mut codes = Vec::with_capacity(t * w.spec.n2);
        packing::unpack_u2(&w.k2p, &mut codes);
        let de = asym::dequantize_key_channelwise(&codes, &w.k2s, &w.k2z, t, w.spec.n2, group);
        for tok in 0..t {
            for j in 0..w.spec.n2 {
                perm[tok * d + base + j] = de[tok * w.spec.n2 + j];
            }
        }
    }
    // Undo the permutation.
    let mut out = vec![0f32; t * d];
    for tok in 0..t {
        for (j, &src) in w.order.iter().enumerate() {
            out[tok * d + src] = perm[tok * d + j];
        }
    }
    out
}

pub fn dequantize_value_window(w: &ValueWindow, d: usize, group: usize) -> Vec<f32> {
    if w.bits == 16 {
        return w.vfull.clone();
    }
    let mut codes = Vec::with_capacity(w.t * d);
    if w.bits == 4 {
        packing::unpack_u4(&w.vp, &mut codes);
    } else {
        packing::unpack_u2(&w.vp, &mut codes);
    }
    asym::dequantize_value_tokenwise(&codes, &w.vs, &w.vz, w.t, d, group)
}

/// Exact storage bytes of a key window (2 bytes per BF16 scalar, 4 per f32
/// scale/zero, 1 per packed byte, 4 per order index) — feeds the memory
/// accountant (Fig. 5).
pub fn key_window_bytes(w: &KeyWindow) -> usize {
    2 * w.k16.len()
        + w.k4p.len()
        + w.k2p.len()
        + 2 * (w.k4s.len() + w.k4z.len() + w.k2s.len() + w.k2z.len())
        + 4 * w.order.len()
}

pub fn value_window_bytes(w: &ValueWindow) -> usize {
    2 * w.vfull.len() + w.vp.len() + 2 * (w.vs.len() + w.vz.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    const G: usize = 32;

    fn opts() -> KeyQuantOpts {
        KeyQuantOpts { clip: 1.0, global_scales: false, group: G }
    }

    fn quant(k: &[f32], t: usize, d: usize, spec: TierSpec, imp: &[f32],
             ordering: Ordering, o: KeyQuantOpts) -> KeyWindow {
        let order = plan_order(ordering, imp, k, t, d);
        quantize_key_window(k, t, d, spec, &order, o)
    }

    fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn roundtrip_error_bounded_all_tiers() {
        let mut rng = Pcg32::seeded(51);
        let (t, d) = (64, 32);
        let spec = TierSpec { n16: 2, n4: 6, n2: 24, v_bits: 2 };
        let k = randn(&mut rng, t * d);
        let imp: Vec<f32> = (0..d).map(|_| rng.f32() + 0.1).collect();
        let w = quant(&k, t, d, spec, &imp, Ordering::Salience, opts());
        let back = dequantize_key_window(&w, d, G);
        // BF16 channels exact, all within 2-bit worst-case bound
        for tok in 0..t {
            for ch in 0..d {
                let err = (back[tok * d + ch] - k[tok * d + ch]).abs();
                assert!(err < 3.0, "unbounded err {err}");
            }
        }
        // the n16 most salient channels are bit-exact
        for j in 0..spec.n16 {
            let ch = w.order[j];
            for tok in 0..t {
                assert_eq!(back[tok * d + ch], k[tok * d + ch]);
            }
        }
    }

    #[test]
    fn salience_tiering_reduces_error_vs_natural() {
        // Inject outlier channels with HIGH importance; salience ordering
        // must protect them and lower q-weighted error vs natural order.
        let mut rng = Pcg32::seeded(52);
        let (t, d) = (64, 32);
        let spec = TierSpec { n16: 2, n4: 6, n2: 24, v_bits: 2 };
        let mut k = randn(&mut rng, t * d);
        let mut imp = vec![0.05f32; d];
        for &ch in &[13usize, 27] {
            imp[ch] = 3.0;
            for tok in 0..t {
                k[tok * d + ch] *= 12.0; // outlier channel
            }
        }
        let q: Vec<f32> = imp.iter().map(|&i| i).collect(); // query ∝ importance
        let weighted_err = |w: &KeyWindow| -> f32 {
            let back = dequantize_key_window(w, d, G);
            let mut e = 0.0;
            for tok in 0..t {
                for ch in 0..d {
                    e += q[ch] * (back[tok * d + ch] - k[tok * d + ch]).abs();
                }
            }
            e
        };
        let w_sal = quant(&k, t, d, spec, &imp, Ordering::Salience, opts());
        let w_nat = quant(&k, t, d, spec, &imp, Ordering::Natural, opts());
        assert!(weighted_err(&w_sal) < 0.5 * weighted_err(&w_nat));
    }

    #[test]
    fn value_window_roundtrip() {
        let mut rng = Pcg32::seeded(53);
        let (t, d) = (32, 32);
        let v = randn(&mut rng, t * d);
        for bits in [2usize, 4] {
            let w = quantize_value_window(&v, t, d, bits, G);
            let back = dequantize_value_window(&w, d, G);
            let max_err = back
                .iter()
                .zip(&v)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let cap = if bits == 2 { 1.5 } else { 0.3 };
            assert!(max_err < cap, "bits={bits} err={max_err}");
        }
    }

    #[test]
    fn bf16_passthrough_exact() {
        let mut rng = Pcg32::seeded(54);
        let (t, d) = (32, 32);
        let spec = TierSpec { n16: d, n4: 0, n2: 0, v_bits: 16 };
        let k = randn(&mut rng, t * d);
        let w = quant(&k, t, d, spec, &vec![1.0; d], Ordering::Natural, opts());
        let back = dequantize_key_window(&w, d, G);
        assert_eq!(back, k);
        let v = randn(&mut rng, t * d);
        let wv = quantize_value_window(&v, t, d, 16, G);
        assert_eq!(dequantize_value_window(&wv, d, G), v);
    }

    #[test]
    fn byte_accounting_matches_layout() {
        let (t, d) = (64, 32);
        let spec = TierSpec { n16: 2, n4: 6, n2: 24, v_bits: 2 };
        let k = vec![0.5f32; t * d];
        let w = quant(&k, t, d, spec, &vec![1.0; d], Ordering::Natural, opts());
        // k16: t*2 bf16; k4p: t*3 bytes; k2p: t*6 bytes; scales/zeros bf16
        let want = 2 * (t * 2) + t * 3 + t * 6 + 2 * (2 * (t / 32) * 6 + 2 * (t / 32) * 24) + 4 * d;
        assert_eq!(key_window_bytes(&w), want);
    }

    #[test]
    fn global_scales_windows_collapse_at_2bit_with_outliers() {
        // KVQuant-style global scales + a few huge outlier tokens => large
        // error for everyone (the Table 3 KV2 collapse mechanism).
        let mut rng = Pcg32::seeded(55);
        let (t, d) = (128, 8);
        let spec = TierSpec { n16: 0, n4: 0, n2: 8, v_bits: 2 };
        let mut k = randn(&mut rng, t * d);
        for ch in 0..d {
            k[5 * d + ch] = 40.0; // outlier token inflates every channel range
        }
        let o_grouped = opts();
        let o_global = KeyQuantOpts { global_scales: true, ..o_grouped };
        let wg = quant(&k, t, d, spec, &vec![1.0; d], Ordering::Natural, o_grouped);
        let wl = quant(&k, t, d, spec, &vec![1.0; d], Ordering::Natural, o_global);
        let err = |w: &KeyWindow| {
            let back = dequantize_key_window(w, d, G);
            back.iter().zip(&k).map(|(a, b)| (a - b).abs()).sum::<f32>()
        };
        assert!(err(&wl) > 1.5 * err(&wg));
    }
}
