//! Hadamard rotation substrate for the RotateKV baseline (Su et al., 2025b).
//!
//! RotateKV spreads key-channel outliers by rotating the head dimension with
//! an orthonormal (scaled) Hadamard matrix before quantization. Because
//! (qR)·(kR) = q·k, the decode graph applies the same rotation to queries
//! (the `rot` input of decode_*.hlo.txt); every other method passes identity.

/// Dense d×d scaled Hadamard (row-major), d must be a power of two.
pub fn hadamard(d: usize) -> Vec<f32> {
    assert!(d.is_power_of_two(), "hadamard needs a power-of-two dim");
    let mut h = vec![1.0f32];
    let mut n = 1;
    while n < d {
        let mut next = vec![0.0f32; 4 * n * n];
        for i in 0..n {
            for j in 0..n {
                let v = h[i * n + j];
                next[i * 2 * n + j] = v;
                next[i * 2 * n + (j + n)] = v;
                next[(i + n) * 2 * n + j] = v;
                next[(i + n) * 2 * n + (j + n)] = -v;
            }
        }
        h = next;
        n *= 2;
    }
    let norm = 1.0 / (d as f32).sqrt();
    h.iter().map(|x| x * norm).collect()
}

pub fn identity(d: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; d * d];
    for i in 0..d {
        m[i * d + i] = 1.0;
    }
    m
}

/// y = x · R for a row vector x (R row-major d×d).
pub fn rotate_vec(x: &[f32], rot: &[f32], out: &mut [f32]) {
    let d = x.len();
    debug_assert_eq!(rot.len(), d * d);
    for j in 0..d {
        let mut acc = 0.0;
        for i in 0..d {
            acc += x[i] * rot[i * d + j];
        }
        out[j] = acc;
    }
}

/// Rotate each row of a [t, d] matrix in place (scratch-allocating).
pub fn rotate_rows(x: &mut [f32], t: usize, d: usize, rot: &[f32]) {
    let mut tmp = vec![0.0f32; d];
    for tok in 0..t {
        let row = &mut x[tok * d..(tok + 1) * d];
        rotate_vec(row, rot, &mut tmp);
        row.copy_from_slice(&tmp);
    }
}

/// y = x · Rᵀ — the inverse of [`rotate_vec`] for orthonormal R (both the
/// scaled Hadamard and identity qualify), so dequantized rotated-space keys
/// can be mapped back to raw channel space for seam-resumed prefill.
pub fn unrotate_vec(x: &[f32], rot: &[f32], out: &mut [f32]) {
    let d = x.len();
    debug_assert_eq!(rot.len(), d * d);
    for j in 0..d {
        let mut acc = 0.0;
        for i in 0..d {
            acc += x[i] * rot[j * d + i];
        }
        out[j] = acc;
    }
}

/// Un-rotate each row of a [t, d] matrix in place (scratch-allocating).
pub fn unrotate_rows(x: &mut [f32], t: usize, d: usize, rot: &[f32]) {
    let mut tmp = vec![0.0f32; d];
    for tok in 0..t {
        let row = &mut x[tok * d..(tok + 1) * d];
        unrotate_vec(row, rot, &mut tmp);
        row.copy_from_slice(&tmp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn hadamard_is_orthonormal() {
        let d = 32;
        let h = hadamard(d);
        for i in 0..d {
            for j in 0..d {
                let dot: f32 = (0..d).map(|k| h[i * d + k] * h[j * d + k]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-5, "({i},{j}) = {dot}");
            }
        }
    }

    #[test]
    fn rotation_preserves_dot_products() {
        let d = 32;
        let h = hadamard(d);
        let mut rng = Pcg32::seeded(41);
        for _ in 0..20 {
            let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let k: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let mut qr = vec![0.0; d];
            let mut kr = vec![0.0; d];
            rotate_vec(&q, &h, &mut qr);
            rotate_vec(&k, &h, &mut kr);
            let dot: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
            let dot_r: f32 = qr.iter().zip(&kr).map(|(a, b)| a * b).sum();
            assert!((dot - dot_r).abs() < 1e-3, "{dot} vs {dot_r}");
        }
    }

    #[test]
    fn rotation_spreads_outliers() {
        // a single spike becomes a flat ±x/sqrt(d) profile — the RotateKV
        // mechanism that shrinks per-channel ranges.
        let d = 32;
        let h = hadamard(d);
        let mut x = vec![0.0f32; d];
        x[5] = 8.0;
        let mut y = vec![0.0; d];
        rotate_vec(&x, &h, &mut y);
        let max = y.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!((max - 8.0 / (d as f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn unrotate_inverts_rotate() {
        let d = 32;
        let h = hadamard(d);
        let mut rng = Pcg32::seeded(97);
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; d];
        let mut back = vec![0.0; d];
        rotate_vec(&x, &h, &mut y);
        unrotate_vec(&y, &h, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn identity_rotation_is_noop() {
        let d = 8;
        let id = identity(d);
        let x: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let mut y = vec![0.0; d];
        rotate_vec(&x, &id, &mut y);
        assert_eq!(x, y);
    }
}
