//! The quantization method zoo: MixKVQ + every baseline the paper compares
//! against (Tables 3, 4, 8; Figs. 1, 5).
//!
//! Each method is a configuration of the shared quantization machinery:
//!
//! | method    | ordering          | rotation | scales            | variant(s)        |
//! |-----------|-------------------|----------|-------------------|-------------------|
//! | MixKVQ    | salience I·S      | no       | grouped           | mix225/mix30/mix325 |
//! | MixKVQ-EO | sensitivity only  | no       | grouped           | (Table 6 ablation) |
//! | KIVI      | natural           | no       | grouped           | kv4/kv2/k4v2/k2v4 |
//! | KVQuant   | natural           | no       | global per-channel| kv4/kv2           |
//! | RotateKV  | natural           | Hadamard | grouped           | kv4/kv2           |
//! | SKVQ      | natural           | no       | grouped, clipped  | kv4/kv2           |
//! | KVTuner   | natural           | no       | grouped           | kvtuner (layer-wise) |
//! | BF16      | —                 | no       | —                 | bf16              |
//!
//! `variant` names a compiled decode graph (artifacts/decode_<variant>.hlo.txt)
//! whose per-layer TierSpecs fix the static shapes.

use crate::quant::rotation;
use crate::quant::salience::Ordering;
use crate::quant::window::KeyQuantOpts;

#[derive(Clone, Debug)]
pub struct Method {
    pub name: String,
    /// decode HLO variant this method runs on
    pub variant: String,
    pub ordering: Ordering,
    pub rotate: bool,
    pub clip: f32,
    pub global_scales: bool,
}

impl Method {
    pub fn bf16() -> Self {
        Self::base("bf16", "bf16")
    }

    /// The paper's method. `variant` ∈ {mix225, mix30, mix325} selects the
    /// effective key bit-width (2.25 / 3.0 / 3.25), mirroring the per-model
    /// threshold outcomes of Appendix C.
    pub fn mixkvq(variant: &str) -> Self {
        let mut m = Self::base(&format!("mixkvq-{variant}"), variant);
        m.ordering = Ordering::Salience;
        m
    }

    /// Table 6 ablation: A_d = S_d (drop the query-aware term).
    pub fn mixkvq_error_only(variant: &str) -> Self {
        let mut m = Self::base(&format!("error-only-{variant}"), variant);
        m.ordering = Ordering::SensitivityOnly;
        m
    }

    /// KIVI (Liu et al. 2024): per-channel K / per-token V, fixed bits.
    /// `bits` ∈ {kv4, kv2, k4v2, k2v4}.
    pub fn kivi(bits: &str) -> Self {
        Self::base(&format!("kivi-{bits}"), bits)
    }

    /// KVQuant (Hooper et al. 2024), simplified to its per-channel
    /// whole-window scale computation (no calibration-time nuq). This is
    /// the variant whose 2-bit mode collapses in Table 3.
    pub fn kvquant(bits: &str) -> Self {
        let mut m = Self::base(&format!("kvquant-{bits}"), bits);
        m.global_scales = true;
        m
    }

    /// RotateKV (Su et al. 2025b): scaled-Hadamard channel rotation before
    /// fixed-bit quantization; queries rotated in-graph via the `rot` input.
    pub fn rotatekv(bits: &str) -> Self {
        let mut m = Self::base(&format!("rotatekv-{bits}"), bits);
        m.rotate = true;
        m
    }

    /// SKVQ (Duanmu et al. 2024), modeled by its clipped dynamic range
    /// (clip ratio 0.92) + the shared sliding full-precision window (the
    /// residual buffer plays that role for every method here).
    pub fn skvq(bits: &str) -> Self {
        let mut m = Self::base(&format!("skvq-{bits}"), bits);
        m.clip = 0.92;
        m
    }

    /// KVTuner (Li et al. 2025): static layer-wise mixed precision — the
    /// `kvtuner` variant pins layers {0,3} at KV4 and {1,2} at KV2
    /// (Appendix B failure analysis).
    pub fn kvtuner() -> Self {
        Self::base("kvtuner", "kvtuner")
    }

    fn base(name: &str, variant: &str) -> Self {
        Method {
            name: name.to_string(),
            variant: variant.to_string(),
            ordering: Ordering::Natural,
            rotate: false,
            clip: 1.0,
            global_scales: false,
        }
    }

    /// Rotation matrix fed to the decode graph (and applied to keys before
    /// quantization). Identity unless the method rotates.
    pub fn rotation(&self, d: usize) -> Vec<f32> {
        if self.rotate {
            rotation::hadamard(d)
        } else {
            rotation::identity(d)
        }
    }

    pub fn key_opts(&self, group: usize) -> KeyQuantOpts {
        KeyQuantOpts { clip: self.clip, global_scales: self.global_scales, group }
    }

    /// The roster evaluated in Table 3 / Fig. 1 (one MixKVQ operating point).
    pub fn table3_roster(mix_variant: &str) -> Vec<Method> {
        vec![
            Method::bf16(),
            Method::kivi("kv4"),
            Method::kivi("kv2"),
            Method::kvquant("kv4"),
            Method::kvquant("kv2"),
            Method::rotatekv("kv4"),
            Method::rotatekv("kv2"),
            Method::skvq("kv4"),
            Method::skvq("kv2"),
            Method::kvtuner(),
            Method::mixkvq(mix_variant),
        ]
    }

    pub fn by_name(name: &str) -> Option<Method> {
        let m = match name {
            "bf16" => Method::bf16(),
            "kivi-kv4" => Method::kivi("kv4"),
            "kivi-kv2" => Method::kivi("kv2"),
            "kivi-k4v2" => Method::kivi("k4v2"),
            "kivi-k2v4" => Method::kivi("k2v4"),
            "kvquant-kv4" => Method::kvquant("kv4"),
            "kvquant-kv2" => Method::kvquant("kv2"),
            "rotatekv-kv4" => Method::rotatekv("kv4"),
            "rotatekv-kv2" => Method::rotatekv("kv2"),
            "skvq-kv4" => Method::skvq("kv4"),
            "skvq-kv2" => Method::skvq("kv2"),
            "kvtuner" => Method::kvtuner(),
            "mixkvq-mix225" => Method::mixkvq("mix225"),
            "mixkvq-mix30" => Method::mixkvq("mix30"),
            "mixkvq-mix325" => Method::mixkvq("mix325"),
            "error-only-mix30" => Method::mixkvq_error_only("mix30"),
            _ => return None,
        };
        Some(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_contains_all_baselines() {
        let r = Method::table3_roster("mix30");
        let names: Vec<&str> = r.iter().map(|m| m.name.as_str()).collect();
        assert!(names.contains(&"bf16"));
        assert!(names.contains(&"kivi-kv2"));
        assert!(names.contains(&"kvquant-kv2"));
        assert!(names.contains(&"rotatekv-kv4"));
        assert!(names.contains(&"kvtuner"));
        assert!(names.contains(&"mixkvq-mix30"));
        assert_eq!(names.len(), 11);
    }

    #[test]
    fn by_name_roundtrip() {
        for m in Method::table3_roster("mix325") {
            let back = Method::by_name(&m.name).expect(&m.name);
            assert_eq!(back.variant, m.variant);
            assert_eq!(back.rotate, m.rotate);
        }
    }

    #[test]
    fn mixkvq_uses_salience_kivi_does_not() {
        assert_eq!(Method::mixkvq("mix30").ordering, Ordering::Salience);
        assert_eq!(Method::kivi("kv2").ordering, Ordering::Natural);
        assert_eq!(
            Method::mixkvq_error_only("mix30").ordering,
            Ordering::SensitivityOnly
        );
    }

    #[test]
    fn skvq_clips_rotatekv_rotates() {
        assert!(Method::skvq("kv2").clip < 1.0);
        assert!(Method::rotatekv("kv2").rotate);
        assert!(Method::kvquant("kv2").global_scales);
        let rot = Method::rotatekv("kv2").rotation(4);
        assert!((rot[0] - 0.5).abs() < 1e-6); // H4/2
        let id = Method::kivi("kv2").rotation(4);
        assert_eq!(id[0], 1.0);
    }
}
