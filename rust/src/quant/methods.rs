//! The quantization method zoo: MixKVQ + every baseline the paper compares
//! against (Tables 3, 4, 8; Figs. 1, 5).
//!
//! Each method is a configuration of the shared quantization machinery:
//!
//! | method    | ordering          | rotation | scales            | variant(s)        |
//! |-----------|-------------------|----------|-------------------|-------------------|
//! | MixKVQ    | salience I·S      | no       | grouped           | mix225/mix30/mix325 |
//! | MixKVQ-EO | sensitivity only  | no       | grouped           | (Table 6 ablation) |
//! | KIVI      | natural           | no       | grouped           | kv4/kv2/k4v2/k2v4 |
//! | KVQuant   | natural           | no       | global per-channel| kv4/kv2           |
//! | RotateKV  | natural           | Hadamard | grouped           | kv4/kv2           |
//! | SKVQ      | natural           | no       | grouped, clipped  | kv4/kv2           |
//! | KVTuner   | natural           | no       | grouped           | kvtuner (layer-wise) |
//! | BF16      | —                 | no       | —                 | bf16              |
//!
//! `variant` names a compiled decode graph (artifacts/decode_<variant>.hlo.txt)
//! whose per-layer TierSpecs fix the static shapes.

use std::fmt;
use std::str::FromStr;

use crate::quant::rotation;
use crate::quant::salience::Ordering;
use crate::quant::window::KeyQuantOpts;

/// MixKVQ operating point: effective key bit-width (Appendix C thresholds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MixOp {
    Mix225,
    Mix30,
    Mix325,
}

impl MixOp {
    pub const ALL: [MixOp; 3] = [MixOp::Mix225, MixOp::Mix30, MixOp::Mix325];

    /// The decode-variant name this operating point compiles to.
    pub fn variant(self) -> &'static str {
        match self {
            MixOp::Mix225 => "mix225",
            MixOp::Mix30 => "mix30",
            MixOp::Mix325 => "mix325",
        }
    }
}

impl FromStr for MixOp {
    type Err = String;

    fn from_str(s: &str) -> Result<MixOp, String> {
        MixOp::ALL
            .into_iter()
            .find(|op| op.variant() == s)
            .ok_or_else(|| format!("unknown MixKVQ operating point `{s}` (mix225|mix30|mix325)"))
    }
}

/// KIVI bit assignment, including the K/V-asymmetric modes (Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KiviBits {
    Kv4,
    Kv2,
    K4V2,
    K2V4,
}

impl KiviBits {
    pub const ALL: [KiviBits; 4] = [KiviBits::Kv4, KiviBits::Kv2, KiviBits::K4V2, KiviBits::K2V4];

    pub fn variant(self) -> &'static str {
        match self {
            KiviBits::Kv4 => "kv4",
            KiviBits::Kv2 => "kv2",
            KiviBits::K4V2 => "k4v2",
            KiviBits::K2V4 => "k2v4",
        }
    }
}

/// Symmetric fixed bit-width used by the KVQuant / RotateKV / SKVQ baselines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FixedBits {
    Kv4,
    Kv2,
}

impl FixedBits {
    pub const ALL: [FixedBits; 2] = [FixedBits::Kv4, FixedBits::Kv2];

    pub fn variant(self) -> &'static str {
        match self {
            FixedBits::Kv4 => "kv4",
            FixedBits::Kv2 => "kv2",
        }
    }
}

/// The typed, closed universe of quantization methods — the single source of
/// truth for method names, decode variants, and configuration. `Display`
/// renders the canonical CLI name, `FromStr` parses it, `MethodSpec::all()`
/// enumerates every constructible variant (so registries and `--method`
/// routing can never drift from the zoo), and `build()` produces the
/// configured [`Method`]. Requests carry an `Option<MethodSpec>` to select
/// their precision policy per-request (see `coordinator::session::Request`).
///
/// Who chooses a spec: an explicit per-request pin always wins and bypasses
/// any server-side policy — the caller takes responsibility for the cost.
/// Unpinned requests are resolved at admission by the server's
/// `quant::policy::PrecisionPolicy` (fixed rung, memory-SLO ladder, or
/// sensitivity-profile Pareto frontier), which may degrade them to a
/// cheaper spec under `KvPool` pressure; with no policy installed the
/// engine's default method applies. Offline code (benches, the experiment
/// harness) builds `Method`s directly and never consults a policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MethodSpec {
    /// The paper's method (salience ordering A = I·S).
    MixKvq { op: MixOp },
    /// Table 6 ablation: sensitivity-only ordering (A = S).
    MixKvqErrorOnly { op: MixOp },
    Kivi { bits: KiviBits },
    KvQuant { bits: FixedBits },
    RotateKv { bits: FixedBits },
    Skvq { bits: FixedBits },
    KvTuner,
    Bf16,
}

impl MethodSpec {
    /// Every constructible method, in roster order. The registry (`by_name`,
    /// `Method::all`, `mixkvq info`) derives from this enumeration.
    pub fn all() -> Vec<MethodSpec> {
        let mut out = vec![MethodSpec::Bf16];
        out.extend(KiviBits::ALL.map(|bits| MethodSpec::Kivi { bits }));
        out.extend(FixedBits::ALL.map(|bits| MethodSpec::KvQuant { bits }));
        out.extend(FixedBits::ALL.map(|bits| MethodSpec::RotateKv { bits }));
        out.extend(FixedBits::ALL.map(|bits| MethodSpec::Skvq { bits }));
        out.push(MethodSpec::KvTuner);
        out.extend(MixOp::ALL.map(|op| MethodSpec::MixKvq { op }));
        out.extend(MixOp::ALL.map(|op| MethodSpec::MixKvqErrorOnly { op }));
        out
    }

    /// The decode-graph variant this method executes on.
    pub fn variant(self) -> &'static str {
        match self {
            MethodSpec::MixKvq { op } | MethodSpec::MixKvqErrorOnly { op } => op.variant(),
            MethodSpec::Kivi { bits } => bits.variant(),
            MethodSpec::KvQuant { bits }
            | MethodSpec::RotateKv { bits }
            | MethodSpec::Skvq { bits } => bits.variant(),
            MethodSpec::KvTuner => "kvtuner",
            MethodSpec::Bf16 => "bf16",
        }
    }

    /// Construct the configured method for this spec.
    pub fn build(self) -> Method {
        match self {
            MethodSpec::MixKvq { op } => Method::mixkvq(op.variant()),
            MethodSpec::MixKvqErrorOnly { op } => Method::mixkvq_error_only(op.variant()),
            MethodSpec::Kivi { bits } => Method::kivi(bits.variant()),
            MethodSpec::KvQuant { bits } => Method::kvquant(bits.variant()),
            MethodSpec::RotateKv { bits } => Method::rotatekv(bits.variant()),
            MethodSpec::Skvq { bits } => Method::skvq(bits.variant()),
            MethodSpec::KvTuner => Method::kvtuner(),
            MethodSpec::Bf16 => Method::bf16(),
        }
    }
}

impl fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MethodSpec::MixKvq { op } => write!(f, "mixkvq-{}", op.variant()),
            MethodSpec::MixKvqErrorOnly { op } => write!(f, "error-only-{}", op.variant()),
            MethodSpec::Kivi { bits } => write!(f, "kivi-{}", bits.variant()),
            MethodSpec::KvQuant { bits } => write!(f, "kvquant-{}", bits.variant()),
            MethodSpec::RotateKv { bits } => write!(f, "rotatekv-{}", bits.variant()),
            MethodSpec::Skvq { bits } => write!(f, "skvq-{}", bits.variant()),
            MethodSpec::KvTuner => write!(f, "kvtuner"),
            MethodSpec::Bf16 => write!(f, "bf16"),
        }
    }
}

impl FromStr for MethodSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<MethodSpec, String> {
        let unknown = || {
            let names: Vec<String> = MethodSpec::all().iter().map(|m| m.to_string()).collect();
            format!("unknown method `{s}` (known: {})", names.join(", "))
        };
        if let Some(op) = s.strip_prefix("mixkvq-") {
            return Ok(MethodSpec::MixKvq { op: op.parse().map_err(|_| unknown())? });
        }
        if let Some(op) = s.strip_prefix("error-only-") {
            return Ok(MethodSpec::MixKvqErrorOnly { op: op.parse().map_err(|_| unknown())? });
        }
        if let Some(bits) = s.strip_prefix("kivi-") {
            let bits = KiviBits::ALL
                .into_iter()
                .find(|b| b.variant() == bits)
                .ok_or_else(unknown)?;
            return Ok(MethodSpec::Kivi { bits });
        }
        let fixed = |bits: &str| FixedBits::ALL.into_iter().find(|b| b.variant() == bits);
        if let Some(bits) = s.strip_prefix("kvquant-") {
            return Ok(MethodSpec::KvQuant { bits: fixed(bits).ok_or_else(unknown)? });
        }
        if let Some(bits) = s.strip_prefix("rotatekv-") {
            return Ok(MethodSpec::RotateKv { bits: fixed(bits).ok_or_else(unknown)? });
        }
        if let Some(bits) = s.strip_prefix("skvq-") {
            return Ok(MethodSpec::Skvq { bits: fixed(bits).ok_or_else(unknown)? });
        }
        match s {
            "kvtuner" => Ok(MethodSpec::KvTuner),
            "bf16" => Ok(MethodSpec::Bf16),
            _ => Err(unknown()),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Method {
    pub name: String,
    /// decode HLO variant this method runs on
    pub variant: String,
    pub ordering: Ordering,
    pub rotate: bool,
    pub clip: f32,
    pub global_scales: bool,
}

impl Method {
    pub fn bf16() -> Self {
        Self::base("bf16", "bf16")
    }

    /// The paper's method. `variant` ∈ {mix225, mix30, mix325} selects the
    /// effective key bit-width (2.25 / 3.0 / 3.25), mirroring the per-model
    /// threshold outcomes of Appendix C.
    pub fn mixkvq(variant: &str) -> Self {
        let mut m = Self::base(&format!("mixkvq-{variant}"), variant);
        m.ordering = Ordering::Salience;
        m
    }

    /// Table 6 ablation: A_d = S_d (drop the query-aware term).
    pub fn mixkvq_error_only(variant: &str) -> Self {
        let mut m = Self::base(&format!("error-only-{variant}"), variant);
        m.ordering = Ordering::SensitivityOnly;
        m
    }

    /// KIVI (Liu et al. 2024): per-channel K / per-token V, fixed bits.
    /// `bits` ∈ {kv4, kv2, k4v2, k2v4}.
    pub fn kivi(bits: &str) -> Self {
        Self::base(&format!("kivi-{bits}"), bits)
    }

    /// KVQuant (Hooper et al. 2024), simplified to its per-channel
    /// whole-window scale computation (no calibration-time nuq). This is
    /// the variant whose 2-bit mode collapses in Table 3.
    pub fn kvquant(bits: &str) -> Self {
        let mut m = Self::base(&format!("kvquant-{bits}"), bits);
        m.global_scales = true;
        m
    }

    /// RotateKV (Su et al. 2025b): scaled-Hadamard channel rotation before
    /// fixed-bit quantization; queries rotated in-graph via the `rot` input.
    pub fn rotatekv(bits: &str) -> Self {
        let mut m = Self::base(&format!("rotatekv-{bits}"), bits);
        m.rotate = true;
        m
    }

    /// SKVQ (Duanmu et al. 2024), modeled by its clipped dynamic range
    /// (clip ratio 0.92) + the shared sliding full-precision window (the
    /// residual buffer plays that role for every method here).
    pub fn skvq(bits: &str) -> Self {
        let mut m = Self::base(&format!("skvq-{bits}"), bits);
        m.clip = 0.92;
        m
    }

    /// KVTuner (Li et al. 2025): static layer-wise mixed precision — the
    /// `kvtuner` variant pins layers {0,3} at KV4 and {1,2} at KV2
    /// (Appendix B failure analysis).
    pub fn kvtuner() -> Self {
        Self::base("kvtuner", "kvtuner")
    }

    fn base(name: &str, variant: &str) -> Self {
        Method {
            name: name.to_string(),
            variant: variant.to_string(),
            ordering: Ordering::Natural,
            rotate: false,
            clip: 1.0,
            global_scales: false,
        }
    }

    /// Rotation matrix fed to the decode graph (and applied to keys before
    /// quantization). Identity unless the method rotates.
    pub fn rotation(&self, d: usize) -> Vec<f32> {
        if self.rotate {
            rotation::hadamard(d)
        } else {
            rotation::identity(d)
        }
    }

    pub fn key_opts(&self, group: usize) -> KeyQuantOpts {
        KeyQuantOpts { clip: self.clip, global_scales: self.global_scales, group }
    }

    /// The roster evaluated in Table 3 / Fig. 1 (one MixKVQ operating
    /// point) — a thin selection over [`MethodSpec::all`].
    pub fn table3_roster(mix_variant: &str) -> Vec<Method> {
        let op: MixOp = mix_variant
            .parse()
            .unwrap_or_else(|e: String| panic!("table3_roster: {e}"));
        MethodSpec::all()
            .into_iter()
            .filter(|s| match s {
                MethodSpec::Kivi { bits } => matches!(bits, KiviBits::Kv4 | KiviBits::Kv2),
                MethodSpec::MixKvq { op: o } => *o == op,
                MethodSpec::MixKvqErrorOnly { .. } => false,
                _ => true,
            })
            .map(MethodSpec::build)
            .collect()
    }

    /// Every constructible method (derived from [`MethodSpec::all`]; listed
    /// by `mixkvq info`).
    pub fn all() -> Vec<Method> {
        MethodSpec::all().into_iter().map(MethodSpec::build).collect()
    }

    /// Look up a method by its canonical name — a thin wrapper over
    /// [`MethodSpec`]'s `FromStr`, so every constructible variant is
    /// reachable by name.
    pub fn by_name(name: &str) -> Option<Method> {
        name.parse::<MethodSpec>().ok().map(MethodSpec::build)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_contains_all_baselines() {
        let r = Method::table3_roster("mix30");
        let names: Vec<&str> = r.iter().map(|m| m.name.as_str()).collect();
        assert!(names.contains(&"bf16"));
        assert!(names.contains(&"kivi-kv2"));
        assert!(names.contains(&"kvquant-kv2"));
        assert!(names.contains(&"rotatekv-kv4"));
        assert!(names.contains(&"kvtuner"));
        assert!(names.contains(&"mixkvq-mix30"));
        assert_eq!(names.len(), 11);
    }

    #[test]
    fn by_name_roundtrip() {
        for m in Method::table3_roster("mix325") {
            let back = Method::by_name(&m.name).expect(&m.name);
            assert_eq!(back.variant, m.variant);
            assert_eq!(back.rotate, m.rotate);
        }
    }

    #[test]
    fn spec_display_parse_roundtrip_covers_every_variant() {
        let all = MethodSpec::all();
        assert_eq!(all.len(), 17);
        let mut names = std::collections::HashSet::new();
        for spec in all {
            let name = spec.to_string();
            assert!(names.insert(name.clone()), "duplicate name {name}");
            let back: MethodSpec = name.parse().expect(&name);
            assert_eq!(back, spec);
            // the built Method's name and variant agree with the spec
            let m = spec.build();
            assert_eq!(m.name, name);
            assert_eq!(m.variant, spec.variant());
            // and the registry reaches it by name (the old match-list gap)
            let by = Method::by_name(&name).expect(&name);
            assert_eq!(by.name, m.name);
            assert_eq!(by.variant, m.variant);
        }
    }

    #[test]
    fn error_only_variants_reachable_by_name() {
        for op in ["mix225", "mix30", "mix325"] {
            let name = format!("error-only-{op}");
            let m = Method::by_name(&name).expect(&name);
            assert_eq!(m.ordering, Ordering::SensitivityOnly);
            assert_eq!(m.variant, op);
        }
        assert!(Method::by_name("error-only-mix999").is_none());
        assert!(Method::by_name("kivi-kv3").is_none());
        assert!("".parse::<MethodSpec>().is_err());
    }

    #[test]
    fn all_matches_spec_enumeration() {
        let methods = Method::all();
        let specs = MethodSpec::all();
        assert_eq!(methods.len(), specs.len());
        for (m, s) in methods.iter().zip(&specs) {
            assert_eq!(m.name, s.to_string());
        }
    }

    #[test]
    fn mixkvq_uses_salience_kivi_does_not() {
        assert_eq!(Method::mixkvq("mix30").ordering, Ordering::Salience);
        assert_eq!(Method::kivi("kv2").ordering, Ordering::Natural);
        assert_eq!(
            Method::mixkvq_error_only("mix30").ordering,
            Ordering::SensitivityOnly
        );
    }

    #[test]
    fn skvq_clips_rotatekv_rotates() {
        assert!(Method::skvq("kv2").clip < 1.0);
        assert!(Method::rotatekv("kv2").rotate);
        assert!(Method::kvquant("kv2").global_scales);
        let rot = Method::rotatekv("kv2").rotation(4);
        assert!((rot[0] - 0.5).abs() < 1e-6); // H4/2
        let id = Method::kivi("kv2").rotation(4);
        assert_eq!(id[0], 1.0);
    }
}
