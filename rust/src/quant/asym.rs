//! Asymmetric B-bit group quantization (Eq. 2–3 of the paper).
//!
//! `z = min(X)`, `s = (max(X) − min(X)) / (2^B − 1)`,
//! `q = round((x − z)/s)`, `x̃ = q·s + z`; `|x − x̃| ≤ s/2` (Appendix A).
//!
//! Matches python/compile/kernels/quant.py: same EPS floor, same rounding
//! direction (ties away from zero vs numpy's ties-to-even differ only *at*
//! exact .5 code boundaries; both stay within the s/2 bound, which is what
//! every consumer relies on).

pub const EPS: f32 = 1e-8;

#[inline]
pub fn qmax(bits: usize) -> u32 {
    (1u32 << bits) - 1
}

/// scale/zero for one group of values.
pub fn quant_params(xs: &[f32], bits: usize) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let scale = ((hi - lo) / qmax(bits) as f32).max(EPS);
    (scale, lo)
}

/// scale/zero with range clipping (SKVQ): shrink the range by `clip` ∈ (0,1]
/// around its midpoint before computing the scale; codes then saturate.
pub fn quant_params_clipped(xs: &[f32], bits: usize, clip: f32) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let mid = 0.5 * (lo + hi);
    let half = 0.5 * (hi - lo) * clip;
    let (lo, hi) = (mid - half, mid + half);
    let scale = ((hi - lo) / qmax(bits) as f32).max(EPS);
    (scale, lo)
}

#[inline]
pub fn encode(x: f32, scale: f32, zero: f32, bits: usize) -> u8 {
    let q = ((x - zero) / scale).round();
    q.clamp(0.0, qmax(bits) as f32) as u8
}

#[inline]
pub fn decode(q: u8, scale: f32, zero: f32) -> f32 {
    q as f32 * scale + zero
}

/// Fused value accumulate over one packed u4 row: `out[j] += p * (c_j *
/// s[j/group] + z[j/group])` straight from the packed bytes — the per-token
/// half of the affine decomposition (quant::packing module docs). `s`/`z`
/// are this token's per-channel-group scales/zeros, `out` is the attention
/// output accumulator ([d]).
pub fn accumulate_row_u4(packed: &[u8], p: f32, s: &[f32], z: &[f32], group: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), packed.len() * 2);
    for (i, &b) in packed.iter().enumerate() {
        let c = crate::quant::packing::unpack_u4_byte(b);
        let j = 2 * i;
        let (g0, g1) = (j / group, (j + 1) / group);
        out[j] += p * (c[0] as f32 * s[g0] + z[g0]);
        out[j + 1] += p * (c[1] as f32 * s[g1] + z[g1]);
    }
}

/// Fused value accumulate over one packed u2 row (4 codes per byte).
pub fn accumulate_row_u2(packed: &[u8], p: f32, s: &[f32], z: &[f32], group: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), packed.len() * 4);
    for (i, &b) in packed.iter().enumerate() {
        let c = crate::quant::packing::unpack_u2_byte(b);
        let j = 4 * i;
        for (k, &ck) in c.iter().enumerate() {
            let g = (j + k) / group;
            out[j + k] += p * (ck as f32 * s[g] + z[g]);
        }
    }
}

/// Per-channel key quantization over a [t, d] row-major window, groups of
/// `group` tokens (KIVI layout). Returns (codes [t*d], scales [t/G, d],
/// zeros [t/G, d]). `clip` = 1.0 disables clipping.
pub fn quantize_key_channelwise(
    k: &[f32],
    t: usize,
    d: usize,
    group: usize,
    bits: usize,
    clip: f32,
) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
    assert_eq!(k.len(), t * d);
    assert!(t % group == 0, "t={t} not a multiple of group={group}");
    let ngroups = t / group;
    let mut codes = vec![0u8; t * d];
    let mut scales = vec![0f32; ngroups * d];
    let mut zeros = vec![0f32; ngroups * d];
    let mut col = Vec::with_capacity(group);
    for g in 0..ngroups {
        for ch in 0..d {
            col.clear();
            for tok in 0..group {
                col.push(k[(g * group + tok) * d + ch]);
            }
            let (s, z) = if clip < 1.0 {
                quant_params_clipped(&col, bits, clip)
            } else {
                quant_params(&col, bits)
            };
            scales[g * d + ch] = s;
            zeros[g * d + ch] = z;
            for tok in 0..group {
                codes[(g * group + tok) * d + ch] = encode(col[tok], s, z, bits);
            }
        }
    }
    (codes, scales, zeros)
}

/// Per-channel key quantization with a single group spanning the whole
/// window (KVQuant-style global per-channel scales). Output scales/zeros
/// are REPLICATED per G-group so the result is ABI-compatible with the
/// grouped decode graph.
pub fn quantize_key_channelwise_global(
    k: &[f32],
    t: usize,
    d: usize,
    group: usize,
    bits: usize,
) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
    assert_eq!(k.len(), t * d);
    let ngroups = t / group;
    let mut codes = vec![0u8; t * d];
    let mut scales = vec![0f32; ngroups * d];
    let mut zeros = vec![0f32; ngroups * d];
    let mut col = Vec::with_capacity(t);
    for ch in 0..d {
        col.clear();
        for tok in 0..t {
            col.push(k[tok * d + ch]);
        }
        let (s, z) = quant_params(&col, bits);
        for tok in 0..t {
            codes[tok * d + ch] = encode(col[tok], s, z, bits);
        }
        for g in 0..ngroups {
            scales[g * d + ch] = s;
            zeros[g * d + ch] = z;
        }
    }
    (codes, scales, zeros)
}

/// Per-token value quantization over [t, d], groups of `group` channels.
/// Returns (codes [t*d], scales [t, d/G], zeros [t, d/G]).
pub fn quantize_value_tokenwise(
    v: &[f32],
    t: usize,
    d: usize,
    group: usize,
    bits: usize,
) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
    assert_eq!(v.len(), t * d);
    assert!(d % group == 0);
    let ngroups = d / group;
    let mut codes = vec![0u8; t * d];
    let mut scales = vec![0f32; t * ngroups];
    let mut zeros = vec![0f32; t * ngroups];
    for tok in 0..t {
        for g in 0..ngroups {
            let row = &v[tok * d + g * group..tok * d + (g + 1) * group];
            let (s, z) = quant_params(row, bits);
            scales[tok * ngroups + g] = s;
            zeros[tok * ngroups + g] = z;
            for (i, &x) in row.iter().enumerate() {
                codes[tok * d + g * group + i] = encode(x, s, z, bits);
            }
        }
    }
    (codes, scales, zeros)
}

/// Dequantize channelwise-grouped key codes back to f32 (reference path).
pub fn dequantize_key_channelwise(
    codes: &[u8],
    scales: &[f32],
    zeros: &[f32],
    t: usize,
    d: usize,
    group: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; t * d];
    for tok in 0..t {
        let g = tok / group;
        for ch in 0..d {
            out[tok * d + ch] = decode(codes[tok * d + ch], scales[g * d + ch], zeros[g * d + ch]);
        }
    }
    out
}

pub fn dequantize_value_tokenwise(
    codes: &[u8],
    scales: &[f32],
    zeros: &[f32],
    t: usize,
    d: usize,
    group: usize,
) -> Vec<f32> {
    let ngroups = d / group;
    let mut out = vec![0f32; t * d];
    for tok in 0..t {
        for ch in 0..d {
            let g = ch / group;
            out[tok * d + ch] =
                decode(codes[tok * d + ch], scales[tok * ngroups + g], zeros[tok * ngroups + g]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn error_bound_property_key() {
        // Appendix A: |x - x~| <= s/2 for every element — swept over random
        // windows, bit-widths, and magnitudes (the proptest invariant).
        let mut rng = Pcg32::seeded(21);
        for case in 0..100 {
            let bits = if case % 2 == 0 { 2 } else { 4 };
            let (t, d, g) = (64, 8, 32);
            let mag = 10f32.powf(rng.f32() * 4.0 - 2.0);
            let k = randn(&mut rng, t * d, mag);
            let (codes, s, z) = quantize_key_channelwise(&k, t, d, g, bits, 1.0);
            let kd = dequantize_key_channelwise(&codes, &s, &z, t, d, g);
            for tok in 0..t {
                for ch in 0..d {
                    let bound = s[(tok / g) * d + ch] / 2.0;
                    let err = (kd[tok * d + ch] - k[tok * d + ch]).abs();
                    assert!(err <= bound * 1.0001 + 1e-6, "err={err} bound={bound}");
                }
            }
        }
    }

    #[test]
    fn error_bound_property_value() {
        let mut rng = Pcg32::seeded(22);
        for case in 0..100 {
            let bits = if case % 2 == 0 { 2 } else { 4 };
            let (t, d, g) = (16, 32, 32);
            let v = randn(&mut rng, t * d, 1.0);
            let (codes, s, z) = quantize_value_tokenwise(&v, t, d, g, bits);
            let vd = dequantize_value_tokenwise(&codes, &s, &z, t, d, g);
            for tok in 0..t {
                for ch in 0..d {
                    let bound = s[tok * (d / g) + ch / g] / 2.0;
                    assert!((vd[tok * d + ch] - v[tok * d + ch]).abs() <= bound * 1.0001 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn global_scales_replicated_per_group() {
        let mut rng = Pcg32::seeded(23);
        let (t, d, g) = (128, 4, 32);
        let k = randn(&mut rng, t * d, 1.0);
        let (_, s, _) = quantize_key_channelwise_global(&k, t, d, g, 2);
        for grp in 1..t / g {
            for ch in 0..d {
                assert_eq!(s[grp * d + ch], s[ch]);
            }
        }
    }

    #[test]
    fn outlier_inflates_other_elements_error() {
        // Section 3.2: one outlier degrades the whole channel group.
        let (t, d, g) = (32, 2, 32);
        let mut k = vec![0f32; t * d];
        for tok in 0..t {
            let x = -1.0 + 2.0 * tok as f32 / (t - 1) as f32;
            k[tok * d] = x;
            k[tok * d + 1] = x;
        }
        k[7 * d + 1] = 100.0;
        let (codes, s, z) = quantize_key_channelwise(&k, t, d, g, 2, 1.0);
        let kd = dequantize_key_channelwise(&codes, &s, &z, t, d, g);
        let err_clean: f32 =
            (0..t).map(|tok| (kd[tok * d] - k[tok * d]).abs()).sum::<f32>() / t as f32;
        let err_outlier: f32 = (0..t)
            .filter(|&tok| tok != 7)
            .map(|tok| (kd[tok * d + 1] - k[tok * d + 1]).abs())
            .sum::<f32>()
            / (t - 1) as f32;
        assert!(err_outlier > 5.0 * err_clean, "{err_outlier} vs {err_clean}");
    }

    #[test]
    fn clipping_shrinks_scale() {
        let xs: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let (s_full, _) = quant_params(&xs, 2);
        let (s_clip, _) = quant_params_clipped(&xs, 2, 0.8);
        assert!((s_clip - 0.8 * s_full).abs() < 1e-6);
    }

    #[test]
    fn constant_input_exact() {
        let xs = vec![3.5f32; 64];
        let (codes, s, z) = quantize_key_channelwise(&xs, 64, 1, 32, 2, 1.0);
        let back = dequantize_key_channelwise(&codes, &s, &z, 64, 1, 32);
        for (a, b) in back.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn accumulate_rows_match_dequant_then_weight() {
        use crate::quant::packing;
        let mut rng = Pcg32::seeded(25);
        for bits in [2usize, 4] {
            let (t, d, g) = (16, 32, 8);
            let v = randn(&mut rng, t * d, 1.0);
            let (codes, s, z) = quantize_value_tokenwise(&v, t, d, g, bits);
            let mut packed = Vec::new();
            for tok in 0..t {
                let row = &codes[tok * d..(tok + 1) * d];
                if bits == 4 {
                    packing::pack_u4(row, &mut packed);
                } else {
                    packing::pack_u2(row, &mut packed);
                }
            }
            let vd = dequantize_value_tokenwise(&codes, &s, &z, t, d, g);
            let probs: Vec<f32> = (0..t).map(|_| rng.f32()).collect();
            let mut want = vec![0f32; d];
            for tok in 0..t {
                for ch in 0..d {
                    want[ch] += probs[tok] * vd[tok * d + ch];
                }
            }
            let mut got = vec![0f32; d];
            let row_bytes = packing::packed_len(d, bits);
            let ng = d / g;
            for tok in 0..t {
                let row = &packed[tok * row_bytes..(tok + 1) * row_bytes];
                let (st, zt) = (&s[tok * ng..(tok + 1) * ng], &z[tok * ng..(tok + 1) * ng]);
                if bits == 4 {
                    accumulate_row_u4(row, probs[tok], st, zt, g, &mut got);
                } else {
                    accumulate_row_u2(row, probs[tok], st, zt, g, &mut got);
                }
            }
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn codes_within_range() {
        let mut rng = Pcg32::seeded(24);
        let v = randn(&mut rng, 32 * 32, 5.0);
        let (codes, _, _) = quantize_value_tokenwise(&v, 32, 32, 32, 2);
        assert!(codes.iter().all(|&c| c < 4));
    }
}
