//! u2/u4 bit packing — bit-for-bit identical to python/compile/kernels/quant.py.
//!
//! ABI: u4 packs channel pair (2j, 2j+1) into byte j with the *even* channel
//! in the low nibble; u2 packs quad (4j..4j+3) with channel 4j in bits 0..1.

/// Pack 4-bit codes (values 0..=15), `codes.len()` must be even.
pub fn pack_u4(codes: &[u8], out: &mut Vec<u8>) {
    debug_assert!(codes.len() % 2 == 0);
    for pair in codes.chunks_exact(2) {
        out.push(pair[0] | (pair[1] << 4));
    }
}

/// Pack 2-bit codes (values 0..=3), `codes.len()` must be a multiple of 4.
pub fn pack_u2(codes: &[u8], out: &mut Vec<u8>) {
    debug_assert!(codes.len() % 4 == 0);
    for quad in codes.chunks_exact(4) {
        out.push(quad[0] | (quad[1] << 2) | (quad[2] << 4) | (quad[3] << 6));
    }
}

pub fn unpack_u4(packed: &[u8], out: &mut Vec<u8>) {
    for &b in packed {
        out.push(b & 0xF);
        out.push((b >> 4) & 0xF);
    }
}

pub fn unpack_u2(packed: &[u8], out: &mut Vec<u8>) {
    for &b in packed {
        out.push(b & 0x3);
        out.push((b >> 2) & 0x3);
        out.push((b >> 4) & 0x3);
        out.push((b >> 6) & 0x3);
    }
}

/// Bytes needed to pack `n` codes at `bits` width (bits ∈ {2, 4, 8}).
pub fn packed_len(n: usize, bits: usize) -> usize {
    n * bits / 8
}

/// LUT-based unpack of a u2 byte into 4 codes — the hot-loop variant used
/// by the reference attention path (see EXPERIMENTS.md §Perf).
#[inline]
pub fn unpack_u2_byte(b: u8) -> [u8; 4] {
    [b & 3, (b >> 2) & 3, (b >> 4) & 3, (b >> 6) & 3]
}

#[inline]
pub fn unpack_u4_byte(b: u8) -> [u8; 2] {
    [b & 0xF, (b >> 4) & 0xF]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn u4_roundtrip_property() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..200 {
            let n = 2 * (1 + rng.below(64) as usize);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let mut packed = Vec::new();
            pack_u4(&codes, &mut packed);
            assert_eq!(packed.len(), packed_len(n, 4));
            let mut back = Vec::new();
            unpack_u4(&packed, &mut back);
            assert_eq!(back, codes);
        }
    }

    #[test]
    fn u2_roundtrip_property() {
        let mut rng = Pcg32::seeded(12);
        for _ in 0..200 {
            let n = 4 * (1 + rng.below(32) as usize);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            let mut packed = Vec::new();
            pack_u2(&codes, &mut packed);
            assert_eq!(packed.len(), packed_len(n, 2));
            let mut back = Vec::new();
            unpack_u2(&packed, &mut back);
            assert_eq!(back, codes);
        }
    }

    #[test]
    fn nibble_order_matches_python_abi() {
        let mut p = Vec::new();
        pack_u4(&[0x3, 0xA], &mut p);
        assert_eq!(p, vec![0x3 | (0xA << 4)]);
    }

    #[test]
    fn crumb_order_matches_python_abi() {
        let mut p = Vec::new();
        pack_u2(&[1, 2, 3, 0], &mut p);
        assert_eq!(p, vec![1 | (2 << 2) | (3 << 4)]);
    }

    #[test]
    fn byte_luts_agree_with_unpack() {
        for b in 0..=255u8 {
            let mut v = Vec::new();
            unpack_u2(&[b], &mut v);
            assert_eq!(v, unpack_u2_byte(b).to_vec());
            let mut v4 = Vec::new();
            unpack_u4(&[b], &mut v4);
            assert_eq!(v4, unpack_u4_byte(b).to_vec());
        }
    }
}
