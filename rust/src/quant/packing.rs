//! u2/u4 bit packing — bit-for-bit identical to python/compile/kernels/quant.py.
//!
//! # Packed-code ABI
//!
//! u4 packs channel pair (2j, 2j+1) into byte j with the *even* channel
//! in the low nibble; u2 packs quad (4j..4j+3) with channel 4j in bits 0..1.
//! A packed *row* is one token's codes for one tier, so a tier of width `n`
//! occupies exactly `packed_len(n, bits)` bytes per token and rows are
//! byte-aligned iff `n % 2 == 0` (u4) / `n % 4 == 0` (u2). Those alignment
//! invariants are `debug_assert!`ed here and in `kvcache::cache::HeadState`;
//! every tier planner (`harness::pareto::tier_grid`, the compiled variants)
//! only emits aligned widths.
//!
//! # Fused packed-code attention (the affine decomposition)
//!
//! The decode hot path never materializes dequantized f32 windows. For a
//! scale-group `g` (G consecutive tokens sharing per-channel scale `s_j`
//! and zero `z_j`), the query-key score decomposes as
//!
//! ```text
//! q · dequant(c_t) = Σ_j q_j (c_{t,j} s_j + z_j)
//!                  = Σ_j (q_j s_j) c_{t,j} + Σ_j q_j z_j
//!                  =      w_g · c_t        +     ζ_g
//! ```
//!
//! so the per-group folded weights `w_g = q ⊙ s_g` and zero-offset
//! `ζ_g = q · z_g` are computed **once per group** and every token in the
//! group costs only a code dot [`dot_packed_u4`]/[`dot_packed_u2`] straight
//! off the packed bytes (LUT nibble/crumb extraction, no unpack buffer).
//! The value side uses the mirrored per-token form
//! `p_t · dequant(v_t) = Σ_j (p_t s_{t,jg}) c_{t,j} + p_t z_{t,jg}`
//! (`quant::asym::accumulate_row_u4`/`_u2`). Consumers:
//! `kvcache::cache::HeadState::{scores_into, values_accumulate_into}` and
//! `model::reference::RefModel::decode_step_into`.
//!
//! # Page layout (pooled storage ABI)
//!
//! Packed rows no longer live in one capacity-sized buffer: the cache
//! stores one **page per quantization group per (layer, kv-head)**, leased
//! from `kvcache::pool::KvPool`. A page's byte arena concatenates
//! `[k4p: G·n4/2 | k2p: G·n2/4 | vp: G·d·v_bits/8]` and its f32 arena
//! `[k16: G·n16 | k4s,k4z: n4 each | k2s,k2z: n2 each | vs,vz: G·d/gv each]`
//! (or `vfull: G·d` at v_bits = 16) — the page size is that sum for the
//! largest `TierSpec` a pool serves, so heterogeneous variants share one
//! free list. Because a group is exactly one scale block, the group's
//! scales/zeros ride inside its page and the same alignment invariants
//! apply **per page**: `n4 % 2 == 0`, `n2 % 4 == 0`, and value rows fill
//! whole bytes, so a token's row inside a page is `ti * row_bytes` with
//! `ti = t mod G`. [`packed_len`] is the single source of those row-byte
//! counts for both the old contiguous maths and `PageLayout`.
//!
//! # Prefill path (direct-to-page quantization)
//!
//! Pages are not only a decode-time layout: the chunked prefill pipeline
//! (`model::reference::PrefillRun`) writes them as the prompt is produced.
//! Its contract, in terms of this ABI:
//!
//! * **chunk size = quantization group alignment** — the forward runs in
//!   G-token tiles, and when a layer closes, its group-aligned window
//!   quantizes through the same `window::quantize_key_window` /
//!   `quantize_value_window` code as a decode-time flush, leasing **one
//!   page per group per (layer, kv-head)** as each group stores
//!   (`RequestCache::store_prefill_layer`). KVQuant-style global scales
//!   still span the whole prefill window because the layer quantizes in
//!   one call — chunking tiles the *forward*, never the scale blocks;
//! * **last-logit-only projection** — the prefill returns logits for the
//!   final position only; full `[T, vocab]` teacher-forced logits exist
//!   only on the oracle path (`RefModel::forward_full`), which the chunked
//!   path must match to ≤1e-4 (tests/blocked_prefill.rs). Prefill
//!   attention runs over the layer's own f32 K/V, so that bound holds for
//!   every method in the roster, 2-bit included;
//! * **bit-identity** — given identical K/V/|q| inputs the chunked sink
//!   stores bit-identical pages to the bulk `load_prefill` path, and
//!   pooled vs private chunked admissions are bitwise equal page for page.
//!
//! # Shared pages are read-only after flush (sharing ABI)
//!
//! The prefill/flush contract above has a corollary the cross-request
//! prefix sharing of `kvcache::radix::RadixTree` depends on: **no code
//! path writes a page after its flush completes**. Appends land in the
//! residual buffer; the next flush quantizes into freshly leased pages;
//! eviction splices table entries without touching bytes. A page is
//! therefore immutable from the moment `store_key_window` /
//! `store_value_window` return, which is exactly what makes it safe to
//! hand the same physical page to N requests behind a refcounted
//! `SharedLease`: co-tenants read the packed rows concurrently with zero
//! coordination, and the packed-row layout, the in-page scales/zeros, and
//! the alignment invariants documented above are the complete contract a
//! reader needs. This holds per *group*, not just per prompt — a radix
//! interior node pins one flushed group's pages, so a frozen-plan partial
//! hit adopts a strict prefix of a producer's pages while the producer
//! (or a deeper sharer) keeps reading the rest; the seam is always a
//! flush boundary, so no page is ever half-shared. The write paths
//! enforce the rule mechanically — a `page_mut` through a shared
//! `PageRef` panics ("copy-on-write violation") rather than corrupt a
//! co-tenant.

/// Pack 4-bit codes (values 0..=15), `codes.len()` must be even.
pub fn pack_u4(codes: &[u8], out: &mut Vec<u8>) {
    debug_assert!(codes.len() % 2 == 0);
    for pair in codes.chunks_exact(2) {
        out.push(pair[0] | (pair[1] << 4));
    }
}

/// Pack 2-bit codes (values 0..=3), `codes.len()` must be a multiple of 4.
pub fn pack_u2(codes: &[u8], out: &mut Vec<u8>) {
    debug_assert!(codes.len() % 4 == 0);
    for quad in codes.chunks_exact(4) {
        out.push(quad[0] | (quad[1] << 2) | (quad[2] << 4) | (quad[3] << 6));
    }
}

pub fn unpack_u4(packed: &[u8], out: &mut Vec<u8>) {
    for &b in packed {
        out.push(b & 0xF);
        out.push((b >> 4) & 0xF);
    }
}

pub fn unpack_u2(packed: &[u8], out: &mut Vec<u8>) {
    for &b in packed {
        out.push(b & 0x3);
        out.push((b >> 2) & 0x3);
        out.push((b >> 4) & 0x3);
        out.push((b >> 6) & 0x3);
    }
}

/// Bytes needed to pack `n` codes at `bits` width (bits ∈ {2, 4, 8}).
///
/// Rounds *up*, and `debug_assert!`s that `n` actually fills whole bytes —
/// an odd tier width would otherwise silently truncate and corrupt the
/// adjacent token's row (packed rows are indexed as `t * packed_len(n, b)`).
pub fn packed_len(n: usize, bits: usize) -> usize {
    debug_assert!(matches!(bits, 2 | 4 | 8), "unsupported pack width {bits}");
    let codes_per_byte = 8 / bits;
    debug_assert!(
        n % codes_per_byte == 0,
        "{n} codes at {bits}-bit do not fill whole bytes ({codes_per_byte} codes/byte)"
    );
    n.div_ceil(codes_per_byte)
}

/// LUT-based unpack of a u2 byte into 4 codes — the hot-loop variant used
/// by the reference attention path (see EXPERIMENTS.md §Perf).
#[inline]
pub fn unpack_u2_byte(b: u8) -> [u8; 4] {
    [b & 3, (b >> 2) & 3, (b >> 4) & 3, (b >> 6) & 3]
}

#[inline]
pub fn unpack_u4_byte(b: u8) -> [u8; 2] {
    [b & 0xF, (b >> 4) & 0xF]
}

/// Fused code dot: `Σ_j w[j] * code_j` over one packed u4 row, never
/// materializing the unpacked codes (see the module docs' affine
/// decomposition — `w` is the per-scale-group folded query `q ⊙ s`).
#[inline]
pub fn dot_packed_u4(packed: &[u8], w: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), packed.len() * 2);
    let mut acc = 0.0f32;
    for (&b, wp) in packed.iter().zip(w.chunks_exact(2)) {
        let c = unpack_u4_byte(b);
        acc += wp[0] * c[0] as f32 + wp[1] * c[1] as f32;
    }
    acc
}

/// Fused code dot over one packed u2 row (4 codes per byte).
#[inline]
pub fn dot_packed_u2(packed: &[u8], w: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), packed.len() * 4);
    let mut acc = 0.0f32;
    for (&b, wq) in packed.iter().zip(w.chunks_exact(4)) {
        let c = unpack_u2_byte(b);
        acc += wq[0] * c[0] as f32
            + wq[1] * c[1] as f32
            + wq[2] * c[2] as f32
            + wq[3] * c[3] as f32;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn u4_roundtrip_property() {
        let mut rng = Pcg32::seeded(11);
        for _ in 0..200 {
            let n = 2 * (1 + rng.below(64) as usize);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let mut packed = Vec::new();
            pack_u4(&codes, &mut packed);
            assert_eq!(packed.len(), packed_len(n, 4));
            let mut back = Vec::new();
            unpack_u4(&packed, &mut back);
            assert_eq!(back, codes);
        }
    }

    #[test]
    fn u2_roundtrip_property() {
        let mut rng = Pcg32::seeded(12);
        for _ in 0..200 {
            let n = 4 * (1 + rng.below(32) as usize);
            let codes: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            let mut packed = Vec::new();
            pack_u2(&codes, &mut packed);
            assert_eq!(packed.len(), packed_len(n, 2));
            let mut back = Vec::new();
            unpack_u2(&packed, &mut back);
            assert_eq!(back, codes);
        }
    }

    #[test]
    fn nibble_order_matches_python_abi() {
        let mut p = Vec::new();
        pack_u4(&[0x3, 0xA], &mut p);
        assert_eq!(p, vec![0x3 | (0xA << 4)]);
    }

    #[test]
    fn crumb_order_matches_python_abi() {
        let mut p = Vec::new();
        pack_u2(&[1, 2, 3, 0], &mut p);
        assert_eq!(p, vec![1 | (2 << 2) | (3 << 4)]);
    }

    #[test]
    fn dot_packed_matches_unpacked_dot() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..100 {
            let n4 = 2 * (1 + rng.below(16) as usize);
            let codes4: Vec<u8> = (0..n4).map(|_| rng.below(16) as u8).collect();
            let w4: Vec<f32> = (0..n4).map(|_| rng.normal()).collect();
            let mut p4 = Vec::new();
            pack_u4(&codes4, &mut p4);
            let want4: f32 = codes4.iter().zip(&w4).map(|(&c, &w)| w * c as f32).sum();
            assert!((dot_packed_u4(&p4, &w4) - want4).abs() < 1e-4 * (1.0 + want4.abs()));

            let n2 = 4 * (1 + rng.below(8) as usize);
            let codes2: Vec<u8> = (0..n2).map(|_| rng.below(4) as u8).collect();
            let w2: Vec<f32> = (0..n2).map(|_| rng.normal()).collect();
            let mut p2 = Vec::new();
            pack_u2(&codes2, &mut p2);
            let want2: f32 = codes2.iter().zip(&w2).map(|(&c, &w)| w * c as f32).sum();
            assert!((dot_packed_u2(&p2, &w2) - want2).abs() < 1e-4 * (1.0 + want2.abs()));
        }
    }

    #[test]
    fn packed_len_rounds_up_on_aligned_widths() {
        assert_eq!(packed_len(32, 2), 8);
        assert_eq!(packed_len(32, 4), 16);
        assert_eq!(packed_len(8, 8), 8);
        assert_eq!(packed_len(0, 2), 0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn packed_len_rejects_misaligned_widths() {
        let _ = packed_len(3, 2); // 3 crumbs don't fill a byte
    }

    #[test]
    fn byte_luts_agree_with_unpack() {
        for b in 0..=255u8 {
            let mut v = Vec::new();
            unpack_u2(&[b], &mut v);
            assert_eq!(v, unpack_u2_byte(b).to_vec());
            let mut v4 = Vec::new();
            unpack_u4(&[b], &mut v4);
            assert_eq!(v4, unpack_u4_byte(b).to_vec());
        }
    }
}
