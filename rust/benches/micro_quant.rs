//! Micro-benchmarks of the quantization hot paths (L3 §Perf targets):
//! pack/unpack, per-channel quantization, window build, dequant views.
//!
//!     cargo bench --bench micro_quant

use mixkvq::quant::asym;
use mixkvq::quant::packing;
use mixkvq::quant::salience::Ordering;
use mixkvq::quant::window::{plan_order, quantize_key_window, quantize_value_window, KeyQuantOpts, TierSpec};
use mixkvq::util::bench::bench;
use mixkvq::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(0);
    let (t, d, g) = (128usize, 32usize, 32usize);
    let k: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let imp: Vec<f32> = (0..d).map(|_| rng.f32() + 0.1).collect();
    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    let opts = KeyQuantOpts { clip: 1.0, global_scales: false, group: g };

    let mut results = Vec::new();

    let codes: Vec<u8> = (0..t * d).map(|_| rng.below(4) as u8).collect();
    results.push(bench("pack_u2 4096 codes", 2000, 300.0, || {
        let mut out = Vec::with_capacity(t * d / 4);
        packing::pack_u2(std::hint::black_box(&codes), &mut out);
        std::hint::black_box(out);
    }));

    let mut packed = Vec::new();
    packing::pack_u2(&codes, &mut packed);
    results.push(bench("unpack_u2 1024 bytes", 2000, 300.0, || {
        let mut out = Vec::with_capacity(t * d);
        packing::unpack_u2(std::hint::black_box(&packed), &mut out);
        std::hint::black_box(out);
    }));

    results.push(bench("quantize_key_channelwise 128x32 @2b", 1000, 400.0, || {
        std::hint::black_box(asym::quantize_key_channelwise(&k, t, d, g, 2, 1.0));
    }));

    results.push(bench("quantize_value_tokenwise 128x32 @2b", 1000, 400.0, || {
        std::hint::black_box(asym::quantize_value_tokenwise(&v, t, d, g, 2));
    }));

    results.push(bench("plan_order (salience) 128x32", 1000, 300.0, || {
        std::hint::black_box(plan_order(Ordering::Salience, &imp, &k, t, d));
    }));

    let order = plan_order(Ordering::Salience, &imp, &k, t, d);
    results.push(bench("quantize_key_window mix30 128x32", 1000, 400.0, || {
        std::hint::black_box(quantize_key_window(&k, t, d, spec, &order, opts));
    }));

    results.push(bench("quantize_value_window @2b 128x32", 1000, 400.0, || {
        std::hint::black_box(quantize_value_window(&v, t, d, 2, g));
    }));

    let w = quantize_key_window(&k, t, d, spec, &order, opts);
    results.push(bench("dequantize_key_window 128x32", 1000, 400.0, || {
        std::hint::black_box(mixkvq::quant::window::dequantize_key_window(&w, d, g));
    }));

    println!("\n== micro_quant ==");
    for r in &results {
        println!("{}", r.report());
    }
}
