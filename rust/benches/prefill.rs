//! `cargo bench --bench prefill` — chunked GEMM-blocked direct-to-page
//! prefill vs the legacy full-materialization path (`forward_full` +
//! `load_prefill`): wall time AND peak-resident prefill bytes.
//!
//! Like ref_decode, this needs **no artifacts** (random weights,
//! build-default shapes), so it always runs — on CI and fresh checkouts —
//! and writes `BENCH_prefill.json` so the perf trajectory has data points.
//! Two prompt lengths; the blocked-chunked path must stay ≥3× faster than
//! legacy at T ≥ 256 and its f32 working set ≥2× smaller (no `[L]`-layer
//! f32 K/V stash, no `T × vocab` logits — ISSUE 4 acceptance bar).
//!
//! The memory numbers are the f32 working sets each path pins while
//! prefilling (measured from the actual buffers: the legacy path's
//! `PrefillOut` stash + full logits + per-layer QKV internals vs the
//! chunked run's arena); the quantized cache both paths produce costs the
//! same and is excluded from the ratio.

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::model::config::Meta;
use mixkvq::model::reference::PrefillRun;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::Method;
use mixkvq::util::bench::bench;
use mixkvq::util::json::{self, Json};
use mixkvq::util::rng::Pcg32;

fn main() {
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let cc = meta.cache.clone(); // capacity 512, residual 128, group 32
    let weights = Weights::random(&mc, 7);
    let spec = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = cc.residual;
    let mut rng = Pcg32::seeded(19);
    let mut results = Vec::new();
    let mut entries = Vec::new();

    for t in [256usize, 512] {
        let driver = RefDriver::new(
            mc.clone(),
            cc.clone(),
            &weights,
            spec.clone(),
            Method::mixkvq("mix30"),
            r_limit,
        );
        let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();

        let chunked = bench(&format!("chunked blocked prefill  T={t}"), 200, 2500.0, || {
            std::hint::black_box(driver.prefill(&prompt).unwrap());
        });
        let legacy = bench(&format!("legacy forward_full      T={t}"), 200, 2500.0, || {
            std::hint::black_box(driver.prefill_legacy(&prompt).unwrap());
        });
        let speedup = legacy.median_ms / chunked.median_ms;

        // --- peak-resident f32 working sets, from the real buffers ------
        // legacy: the [L]-layer PrefillOut stash + the T×vocab logits it
        // computes and production discards + forward_full's per-layer
        // q_all/k_all/v_all internals + the [T, d] hidden state
        let (full_logits, pre) = driver.model.forward_full(&prompt);
        let stash: usize = pre.k.iter().map(Vec::len).sum::<usize>()
            + pre.v.iter().map(Vec::len).sum::<usize>()
            + pre.qabs.iter().map(Vec::len).sum::<usize>();
        let (hq, hkv, dh) = (mc.n_q_heads, mc.n_kv_heads, mc.d_head);
        let internals = t * mc.d_model + t * (hq + 2 * hkv) * dh;
        let legacy_peak = 4 * (full_logits.len() + stash + internals);
        // chunked: one arena — h + ONE layer's K/V + chunk tiles + the
        // last-position logits
        let chunked_peak = PrefillRun::new(&mc, t, cc.group).resident_bytes();
        let mem_ratio = legacy_peak as f64 / chunked_peak as f64;

        println!(
            "T={t}: chunked {:.3} ms  legacy {:.3} ms  speedup {:.2}x{}",
            chunked.median_ms,
            legacy.median_ms,
            speedup,
            if speedup < 3.0 { "  (below the 3x bar!)" } else { "" }
        );
        println!(
            "      peak resident {chunked_peak} B (chunked arena) vs {legacy_peak} B legacy \
             f32 working set ({mem_ratio:.2}x{})",
            if mem_ratio < 2.0 { "  (below the 2x bar!)" } else { "" }
        );
        entries.push(json::obj(vec![
            ("t", json::num(t as f64)),
            ("chunked_ms", json::num(chunked.median_ms)),
            ("legacy_ms", json::num(legacy.median_ms)),
            ("speedup", json::num(speedup)),
            ("chunked_peak_bytes", json::num(chunked_peak as f64)),
            ("legacy_peak_bytes", json::num(legacy_peak as f64)),
            ("peak_ratio", json::num(mem_ratio)),
        ]));
        results.push(chunked);
        results.push(legacy);
    }

    println!("\n== prefill ==");
    for r in &results {
        println!("{}", r.report());
    }

    let report = json::obj(vec![
        ("bench", json::s("prefill")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_prefill.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_prefill.json");
}
