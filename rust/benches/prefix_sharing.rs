//! `cargo bench --bench prefix_sharing` — cross-request prefix page
//! sharing: K requests over one prompt adopt the registered shared pages
//! (a full `RadixTree` hit) instead of each running a private chunked
//! prefill.
//!
//! Like ref_decode/prefill this needs **no artifacts** (random weights,
//! build-default shapes), so it always runs — on CI and fresh checkouts —
//! and writes `BENCH_prefix_sharing.json`, which the CI `bench-gate` binary
//! holds to the ROADMAP bars: K sharers must hold ≥2× fewer prefix pages
//! than K private copies (page dedup), and hits must actually skip their
//! prefill chunks (compute skipped, not just bytes). The timed comparison
//! is the hit-install path (reference pages + copy the bounded residual
//! tail) against the full chunked prefill it replaces.

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::kvcache::cache::RequestCache;
use mixkvq::kvcache::pool::{prefix_seed, KvPool};
use mixkvq::kvcache::radix::{PrefixProbe, RadixTree};
use mixkvq::model::config::Meta;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::Method;
use mixkvq::util::bench::bench;
use mixkvq::util::json::{self, Json};
use mixkvq::util::rng::Pcg32;

fn main() {
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let cc = meta.cache.clone(); // capacity 512, residual 128, group 32
    let weights = Weights::random(&mc, 7);
    let specs = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = cc.residual;
    let k_req = 4usize;
    let mut rng = Pcg32::seeded(23);
    let mut results = Vec::new();
    let mut entries = Vec::new();

    for t in [256usize, 512] {
        let driver = RefDriver::new(
            mc.clone(),
            cc.clone(),
            &weights,
            specs.clone(),
            Method::mixkvq("mix30"),
            r_limit,
        );
        let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();

        // private-mode yardstick: what ONE request's prefill leases
        let (private_cache, _) = driver.prefill(&prompt).unwrap();
        let pages_per_req = private_cache.leased_pages();
        drop(private_cache);

        // the serving configuration: bounded prewarmed pool + prefix tree
        let pool = KvPool::for_specs(specs.iter(), mc.d_head, cc.group, Some(4 * pages_per_req));
        pool.prewarm(4 * pages_per_req);
        let mut index = RadixTree::new(2 * pages_per_req, pool.page_deploy_bytes());
        let seed = prefix_seed(
            &driver.method.name,
            r_limit,
            cc.group,
            cc.capacity,
            mc.n_layers,
            mc.n_kv_heads,
            mc.d_head,
        );

        let (mut producer, last) = driver.prefill_pooled(&pool, &prompt).unwrap();
        assert!(producer.register_prefix(&mut index, seed, &prompt, &last));
        let prefix_pages = pool.leased();
        assert_eq!(prefix_pages, pages_per_req, "registration must not lease");

        // timed: adopting the registered prompt vs prefilling it
        let hit = bench(&format!("prefix-hit install       T={t}"), 300, 2500.0, || {
            let mut c = RequestCache::new_in(
                &pool,
                &mc,
                &cc,
                &specs,
                Method::mixkvq("mix30"),
                r_limit,
            );
            let m = match index.lookup(seed, &prompt, cc.group, 0) {
                PrefixProbe::Full(m) => m,
                _ => panic!("expected a full prefix hit"),
            };
            c.install_prefix(&m).unwrap();
            drop(m);
            std::hint::black_box(&c);
        });
        let miss = bench(&format!("full chunked prefill     T={t}"), 100, 2500.0, || {
            std::hint::black_box(driver.prefill_pooled(&pool, &prompt).unwrap());
        });
        let speedup = miss.median_ms / hit.median_ms;

        // K resident sharers (producer + K-1 hits): page dedup in the pool
        let sharers: Vec<RequestCache> = (0..k_req - 1)
            .map(|_| {
                let mut c = RequestCache::new_in(
                    &pool,
                    &mc,
                    &cc,
                    &specs,
                    Method::mixkvq("mix30"),
                    r_limit,
                );
                let m = match index.lookup(seed, &prompt, cc.group, 0) {
                    PrefixProbe::Full(m) => m,
                    _ => panic!("expected a full prefix hit"),
                };
                c.install_prefix(&m).unwrap();
                drop(m);
                c
            })
            .collect();
        let shared_pages = pool.leased();
        let private_equiv = k_req * pages_per_req;
        let dedup_ratio = private_equiv as f64 / shared_pages.max(1) as f64;
        // compute skipped: every hit skips the whole prompt's chunk grid
        let chunks_per_prefill = t.div_ceil(cc.group) * mc.n_layers;
        let chunks_skipped = (k_req - 1) * chunks_per_prefill;
        let bytes_deduped = index.stats().bytes_deduped;

        println!(
            "T={t} K={k_req}: hit {:.3} ms  full prefill {:.3} ms  install speedup {:.1}x",
            hit.median_ms, miss.median_ms, speedup
        );
        println!(
            "      pages {shared_pages} shared vs {private_equiv} private-mode \
             ({dedup_ratio:.2}x dedup{}), {chunks_skipped} chunks skipped, \
             {bytes_deduped} B deduped",
            if dedup_ratio < 2.0 { "  (below the 2x bar!)" } else { "" }
        );
        entries.push(json::obj(vec![
            ("t", json::num(t as f64)),
            ("k", json::num(k_req as f64)),
            ("hit_install_ms", json::num(hit.median_ms)),
            ("full_prefill_ms", json::num(miss.median_ms)),
            ("install_speedup", json::num(speedup)),
            ("pages_shared", json::num(shared_pages as f64)),
            ("pages_private_equiv", json::num(private_equiv as f64)),
            ("dedup_ratio", json::num(dedup_ratio)),
            ("chunks_skipped", json::num(chunks_skipped as f64)),
            ("bytes_deduped", json::num(bytes_deduped as f64)),
        ]));
        results.push(hit);
        results.push(miss);
        drop(sharers);
        drop(producer);
        assert_eq!(pool.leased(), prefix_pages, "index must be the last holder");
    }

    println!("\n== prefix_sharing ==");
    for r in &results {
        println!("{}", r.report());
    }

    let report = json::obj(vec![
        ("bench", json::s("prefix_sharing")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_prefix_sharing.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_prefix_sharing.json");
}
