//! `cargo bench --bench prefix_radix` — the shared-system-prompt serving
//! workload over the radix prefix tree: one producer registers a 2048-token
//! system prefix, then K consumers arrive with divergent ~64-token suffixes
//! and take frozen-plan *partial* hits through the unified
//! `Engine::admit_prefill` API, resuming their chunked prefills from the
//! divergence seam instead of token 0.
//!
//! Like the other reference benches this needs **no artifacts** (random
//! weights, build-default shapes with a widened cache capacity), so it
//! always runs and writes `BENCH_prefix_radix.json`, which the CI
//! `bench-gate` binary holds to the ROADMAP bars:
//!
//! * page dedup ≥2×: K resident partial-hit consumers must hold ≥2× fewer
//!   pool pages than K private prefills would;
//! * zero same-seed fingerprint drift: the whole scenario runs twice from
//!   identical seeds with the tree enabled and must produce bit-identical
//!   logits, admission verdicts, and lease counts;
//! * frozen-plan error: a `frozen_plan_sweep` over the serving roster —
//!   every method whose frozen-plan default is ON must measure inside
//!   `FROZEN_PLAN_NLL_BUDGET`.

use std::cell::RefCell;
use std::rc::Rc;

use mixkvq::coordinator::engine::{Engine, PrefillAdmission};
use mixkvq::harness::profiling::{frozen_plan_sweep, FrozenPlanConfig};
use mixkvq::kvcache::radix::RadixTree;
use mixkvq::model::config::Meta;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::bench::bench;
use mixkvq::util::json::{self, Json};
use mixkvq::util::rng::Pcg32;

const SHARED_TOKENS: usize = 2048;
const SUFFIX_TOKENS: usize = 64;
const K_CONSUMERS: usize = 4;
const SEED: u64 = 4801;

/// Build-default shapes except the cache window, widened so a 2048-token
/// system prefix fits the quantized window (default capacity is 512).
fn bench_meta() -> Meta {
    let mut meta = Meta::default_build();
    meta.cache.capacity = 2048;
    meta
}

fn fnv1a(acc: u64, bytes: &[u8]) -> u64 {
    let mut h = acc;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn mix_usize(acc: u64, v: usize) -> u64 {
    fnv1a(acc, &(v as u64).to_le_bytes())
}

fn mix_logits(acc: u64, logits: &[f32]) -> u64 {
    let mut h = acc;
    for &x in logits {
        h = fnv1a(h, &x.to_bits().to_le_bytes());
    }
    h
}

struct Scenario {
    fingerprint: u64,
    matched_tokens: usize,
    seam: usize,
    pages_shared: usize,
    pages_private_equiv: usize,
    dedup_ratio: f64,
    chunks_skipped: usize,
    bytes_deduped: u64,
}

/// One full pass of the workload: producer registration, then K staggered
/// consumers taking frozen-plan partial hits through `admit_prefill`.
/// Everything observable folds into the fingerprint so a repeat run from
/// the same seed must reproduce it bit-for-bit.
fn run_scenario(
    meta: &Meta,
    method: &Method,
    producer_prompt: &[i32],
    consumer_prompts: &[Vec<i32>],
    private_pages_per_consumer: usize,
) -> Scenario {
    let r_limit = meta.cache.residual;
    let group = meta.cache.group;
    let mut engine =
        Engine::new_reference(meta.clone(), SEED, method.clone(), r_limit).expect("engine");
    let pool = engine.build_shared_pool(64 << 20);
    let page_bytes = pool.page_deploy_bytes();
    engine.set_kv_pool(pool);
    let tree = Rc::new(RefCell::new(RadixTree::new(1 << 20, page_bytes)));
    engine.set_prefix_tree(tree.clone());

    let mut fp = 0xcbf29ce484222325u64;

    // producer: a miss, run to completion, register the chain
    let (adm, mut pcp) = engine.admit_prefill(producer_prompt, method).expect("producer admit");
    assert_eq!(adm, PrefillAdmission::Miss, "producer must miss the empty tree");
    while !engine
        .advance_prefill_chunked(&mut pcp, producer_prompt, usize::MAX)
        .expect("producer chunk")
    {}
    let last = pcp.run.last_logits().to_vec();
    assert!(
        engine.register_prefix(&mut pcp.cache, producer_prompt, method, &last),
        "producer registration refused"
    );
    fp = mix_logits(fp, &last);
    drop(pcp);

    // staggered consumers: admit all K, then round-robin small chunk
    // budgets so their resumed prefills are in flight concurrently
    let mut matched_tokens = 0;
    let mut seam_at = 0;
    let mut live = Vec::new();
    for prompt in consumer_prompts {
        let (adm, cp) = engine.admit_prefill(prompt, method).expect("consumer admit");
        match adm {
            PrefillAdmission::PartialHit { matched_tokens: m, seam } => {
                matched_tokens = m;
                seam_at = seam;
                fp = mix_usize(mix_usize(fp, m), seam);
            }
            other => panic!("consumer expected a partial hit, got {other:?}"),
        }
        live.push(cp);
    }
    let mut done = vec![false; live.len()];
    while done.iter().any(|d| !d) {
        for (i, cp) in live.iter_mut().enumerate() {
            if !done[i] {
                done[i] = engine
                    .advance_prefill_chunked(cp, &consumer_prompts[i], 4)
                    .expect("consumer chunk");
            }
        }
    }
    for cp in &live {
        fp = mix_logits(fp, cp.run.last_logits());
        fp = mix_usize(fp, cp.cache.leased_pages());
    }

    // dedup accounting while all K consumers are resident
    let pages_shared = engine.kv_pool().expect("pool").leased();
    let pages_private_equiv = K_CONSUMERS * private_pages_per_consumer;
    let dedup_ratio = pages_private_equiv as f64 / pages_shared.max(1) as f64;
    let chunks_skipped = K_CONSUMERS * (seam_at / group) * meta.model.n_layers;
    let stats = tree.borrow().stats();
    fp = mix_usize(fp, pages_shared);
    fp = mix_usize(fp, stats.partial_hits as usize);
    tree.borrow().audit().expect("tree audit");

    drop(live);
    assert_eq!(
        engine.kv_pool().expect("pool").leased(),
        tree.borrow().pages_pinned(),
        "after the consumers retire the tree must be the only holder"
    );

    Scenario {
        fingerprint: fp,
        matched_tokens,
        seam: seam_at,
        pages_shared,
        pages_private_equiv,
        dedup_ratio,
        chunks_skipped,
        bytes_deduped: stats.bytes_deduped,
    }
}

fn main() {
    let meta = bench_meta();
    let method = Method::mixkvq("mix30");
    let r_limit = meta.cache.residual;
    let group = meta.cache.group;
    assert_eq!(SHARED_TOKENS % group, 0);

    let mut rng = Pcg32::seeded(SEED);
    let vocab = meta.model.vocab as i32;
    let mut toks = |n: usize| -> Vec<i32> {
        (0..n).map(|_| (rng.next_u32() as i32).rem_euclid(vocab)).collect()
    };
    let shared = toks(SHARED_TOKENS);
    // the producer ends exactly r_limit past the shared boundary so its
    // quantized window — the registered chain — covers the prefix precisely
    let producer_prompt: Vec<i32> =
        shared.iter().copied().chain(toks(r_limit)).collect();
    let consumer_prompts: Vec<Vec<i32>> = (0..K_CONSUMERS)
        .map(|_| shared.iter().copied().chain(toks(SUFFIX_TOKENS)).collect())
        .collect();
    let t = consumer_prompts[0].len();

    // private-mode yardstick: the same consumer prompt prefilled on a
    // tree-less but otherwise identical engine
    let mut private_engine =
        Engine::new_reference(meta.clone(), SEED, method.clone(), r_limit).expect("engine");
    let pool = private_engine.build_shared_pool(64 << 20);
    private_engine.set_kv_pool(pool);
    let (adm, mut ecp) =
        private_engine.admit_prefill(&consumer_prompts[0], &method).expect("private admit");
    assert_eq!(adm, PrefillAdmission::Miss);
    while !private_engine
        .advance_prefill_chunked(&mut ecp, &consumer_prompts[0], usize::MAX)
        .expect("private chunk")
    {}
    let private_pages_per_consumer = ecp.cache.leased_pages();
    drop(ecp);

    // same-seed determinism: the whole scenario twice, bit-for-bit
    let first = run_scenario(&meta, &method, &producer_prompt, &consumer_prompts, private_pages_per_consumer);
    let second = run_scenario(&meta, &method, &producer_prompt, &consumer_prompts, private_pages_per_consumer);
    let drift = first.fingerprint != second.fingerprint;
    assert!(!drift, "same-seed fingerprint drift with the tree enabled");

    // timed: a frozen-plan partial-hit resume vs the full prefill it skips
    let mut timed_engine =
        Engine::new_reference(meta.clone(), SEED, method.clone(), r_limit).expect("engine");
    let pool = timed_engine.build_shared_pool(64 << 20);
    let page_bytes = pool.page_deploy_bytes();
    timed_engine.set_kv_pool(pool);
    timed_engine.set_prefix_tree(Rc::new(RefCell::new(RadixTree::new(1 << 20, page_bytes))));
    let (_, mut pcp) =
        timed_engine.admit_prefill(&producer_prompt, &method).expect("producer admit");
    while !timed_engine
        .advance_prefill_chunked(&mut pcp, &producer_prompt, usize::MAX)
        .expect("producer chunk")
    {}
    let last = pcp.run.last_logits().to_vec();
    assert!(timed_engine.register_prefix(&mut pcp.cache, &producer_prompt, &method, &last));
    drop(pcp);
    let hit = bench(&format!("partial-hit resume      T={t}"), 40, 2500.0, || {
        let (adm, mut cp) =
            timed_engine.admit_prefill(&consumer_prompts[0], &method).expect("admit");
        assert!(matches!(adm, PrefillAdmission::PartialHit { .. }));
        while !timed_engine
            .advance_prefill_chunked(&mut cp, &consumer_prompts[0], usize::MAX)
            .expect("chunk")
        {}
        std::hint::black_box(&cp);
    });
    let miss = bench(&format!("full chunked prefill    T={t}"), 20, 2500.0, || {
        let (_, mut cp) =
            private_engine.admit_prefill(&consumer_prompts[0], &method).expect("admit");
        while !private_engine
            .advance_prefill_chunked(&mut cp, &consumer_prompts[0], usize::MAX)
            .expect("chunk")
        {}
        std::hint::black_box(&cp);
    });
    let speedup = miss.median_ms / hit.median_ms;

    // frozen-plan ablation over the serving roster (build-default shapes —
    // the sweep sizes its own prompts)
    let sweep_specs: Vec<MethodSpec> = ["mixkvq-mix30", "bf16", "kivi-kv2", "kvquant-kv2", "kvtuner"]
        .iter()
        .map(|n| n.parse::<MethodSpec>().expect("roster name"))
        .collect();
    let sweep = frozen_plan_sweep(&Meta::default_build(), &sweep_specs, &FrozenPlanConfig::default())
        .expect("frozen-plan sweep");

    println!(
        "T={t} K={K_CONSUMERS}: matched {} of {SHARED_TOKENS} shared tokens, seam {}",
        first.matched_tokens, first.seam
    );
    println!(
        "      pages {} shared vs {} private-mode ({:.2}x dedup{}), {} chunks skipped, {} B deduped",
        first.pages_shared,
        first.pages_private_equiv,
        first.dedup_ratio,
        if first.dedup_ratio < 2.0 { "  (below the 2x bar!)" } else { "" },
        first.chunks_skipped,
        first.bytes_deduped
    );
    println!(
        "      resume {:.3} ms vs full prefill {:.3} ms ({speedup:.1}x), fingerprint {:#018x} (repeat drift: {drift})",
        hit.median_ms, miss.median_ms, first.fingerprint
    );
    for e in &sweep {
        println!(
            "      frozen-plan {:<16} default_on={} nll_delta={:.4} within_budget={}",
            e.spec.to_string(),
            e.default_on,
            e.nll_delta,
            e.within_budget
        );
    }
    println!("\n== prefix_radix ==");
    println!("{}", hit.report());
    println!("{}", miss.report());

    let entries = vec![json::obj(vec![
        ("t", json::num(t as f64)),
        ("k", json::num(K_CONSUMERS as f64)),
        ("shared_tokens", json::num(SHARED_TOKENS as f64)),
        ("matched_tokens", json::num(first.matched_tokens as f64)),
        ("seam", json::num(first.seam as f64)),
        ("hit_resume_ms", json::num(hit.median_ms)),
        ("full_prefill_ms", json::num(miss.median_ms)),
        ("resume_speedup", json::num(speedup)),
        ("pages_shared", json::num(first.pages_shared as f64)),
        ("pages_private_equiv", json::num(first.pages_private_equiv as f64)),
        ("dedup_ratio", json::num(first.dedup_ratio)),
        ("chunks_skipped", json::num(first.chunks_skipped as f64)),
        ("bytes_deduped", json::num(first.bytes_deduped as f64)),
    ])];
    let frozen = sweep
        .iter()
        .map(|e| {
            json::obj(vec![
                ("method", json::s(&e.spec.to_string())),
                ("default_on", Json::Bool(e.default_on)),
                ("logit_err", json::num(e.logit_err)),
                ("nll_delta", json::num(e.nll_delta)),
                ("within_budget", Json::Bool(e.within_budget)),
            ])
        })
        .collect();
    let report = json::obj(vec![
        ("bench", json::s("prefix_radix")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(entries)),
        ("fingerprint", json::s(&format!("{:#018x}", first.fingerprint))),
        ("fingerprint_repeat", json::s(&format!("{:#018x}", second.fingerprint))),
        ("fingerprint_drift", Json::Bool(drift)),
        ("frozen_plan", Json::Arr(frozen)),
    ]);
    std::fs::write("BENCH_prefix_radix.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_prefix_radix.json");
}
