//! `cargo bench --bench fig7_pareto` — regenerates: Fig. 7 Pareto frontier.
//! Set MIXKVQ_QUICK=1 for a reduced-size run.

use mixkvq::harness::experiments::{run, ExpCtx};

fn main() {
    let artifacts = std::path::PathBuf::from(
        std::env::var("MIXKVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let quick = std::env::var("MIXKVQ_QUICK").is_ok();
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP fig7_pareto: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let ctx = ExpCtx::new(&artifacts, quick);
    let t0 = std::time::Instant::now();
    match run(&ctx, "fig7") {
        Ok(table) => {
            println!("{}", table.print());
            println!("[fig7_pareto] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("[fig7_pareto] FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}
