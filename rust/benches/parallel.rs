//! `cargo bench --bench parallel` — worker-pool scaling through the real
//! serving stack: the same seeded closed-loop workload at `workers = 1`
//! and `workers = 4`, timed end to end.
//!
//! Like the other artifact-free benches this needs no artifacts (random
//! weights, build-default shapes), so it always runs — on CI and fresh
//! checkouts — and writes `BENCH_parallel.json` for the bench gate, which
//! holds two bars over it:
//!
//! * tick throughput at 4 workers must stay ≥ 2× the single-threaded run
//!   (the ISSUE acceptance bar for the worker pool);
//! * ZERO fingerprint drift between the widths — the parallel path is a
//!   perf optimisation, not a semantics change, so both runs must report
//!   the identical outcome fingerprint (ids, reasons, token streams,
//!   tenant counters) and the identical tick count.
//!
//! Because outcomes are bit-identical, the two runs execute the *same*
//! tick sequence — wall-time ratio IS the scaling, with no workload noise.

use std::time::Instant;

use mixkvq::coordinator::engine::Engine;
use mixkvq::harness::traffic::{self as tr, Arrival, TrafficConfig};
use mixkvq::model::config::Meta;
use mixkvq::quant::methods::Method;
use mixkvq::util::json::{self, Json};

fn main() {
    let cfg_at = |workers: usize| TrafficConfig {
        seed: 21,
        sessions: 48,
        tenants: 4,
        // closed loop keeps the decode batch full: scaling measures the
        // sharded compute, not arrival gaps
        arrival: Arrival::ClosedLoop { concurrency: 8, think_ticks: 1 },
        max_new: 32,
        prompt_lo: 48,
        prompt_hi: 96,
        workers,
        ..TrafficConfig::default()
    };
    let engine = || {
        Engine::new_reference(Meta::default_build(), 11, Method::bf16(), 32)
            .expect("reference engine")
    };

    let mut entries = Vec::new();
    let mut reports = Vec::new();
    for workers in [1usize, 4] {
        let cfg = cfg_at(workers);
        let t0 = Instant::now();
        let r = tr::run(engine(), &cfg).expect("traffic run");
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let ticks_per_s = r.ticks as f64 / (wall_ms / 1e3).max(1e-9);
        println!(
            "workers={workers}: {} sessions, {} ticks in {:.1} ms ({:.1} ticks/s), \
             fingerprint {:016x}",
            r.completed, r.ticks, wall_ms, ticks_per_s, r.fingerprint
        );
        assert_eq!(r.completed, cfg.sessions, "workers={workers}: sessions stranded");
        entries.push(json::obj(vec![
            ("workers", json::num(workers as f64)),
            ("wall_ms", json::num(wall_ms)),
            ("ticks", json::num(r.ticks as f64)),
            ("ticks_per_s", json::num(ticks_per_s)),
            ("fingerprint", json::s(&format!("{:016x}", r.fingerprint))),
        ]));
        reports.push(r);
    }

    let drift = reports[0].fingerprint != reports[1].fingerprint
        || reports[0].ticks != reports[1].ticks;
    let e = |i: usize, k: &str| entries[i].get(k).unwrap().as_f64().unwrap();
    let scaling = e(1, "ticks_per_s") / e(0, "ticks_per_s").max(1e-9);
    println!(
        "parallel scaling: {scaling:.2}x tick throughput at 4 workers{}{}",
        if scaling < 2.0 { "  (below the 2x bar!)" } else { "" },
        if drift { "  FINGERPRINT DRIFT" } else { "" }
    );

    let report = json::obj(vec![
        ("bench", json::s("parallel")),
        ("entries", Json::Arr(entries)),
        ("scaling", json::num(scaling)),
        ("fingerprint_drift", Json::Bool(drift)),
    ]);
    std::fs::write("BENCH_parallel.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_parallel.json");
}
