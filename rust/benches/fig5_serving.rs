//! `cargo bench --bench fig5_serving` — regenerates: Fig. 5 serving memory+throughput.
//! Set MIXKVQ_QUICK=1 for a reduced-size run.

use mixkvq::harness::experiments::{run, ExpCtx};

fn main() {
    let artifacts = std::path::PathBuf::from(
        std::env::var("MIXKVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let quick = std::env::var("MIXKVQ_QUICK").is_ok();
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP fig5_serving: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let ctx = ExpCtx::new(&artifacts, quick);
    let t0 = std::time::Instant::now();
    match run(&ctx, "fig5") {
        Ok(table) => {
            println!("{}", table.print());
            println!("[fig5_serving] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("[fig5_serving] FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}
