//! `cargo bench --bench ref_decode` — reference-path decode: fused
//! packed-code attention vs the legacy dequantize-then-attend path.
//!
//! Unlike the engine benches this needs **no artifacts** (random weights,
//! build-default shapes), so it always runs — on CI and on fresh checkouts —
//! and writes `BENCH_ref_decode.json` so the perf trajectory has data
//! points. Two context lengths; the fused path must stay ≥3× faster at
//! qlen ≥ 256 (ISSUE 2 acceptance bar).

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::model::config::Meta;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::Method;
use mixkvq::util::bench::bench;
use mixkvq::util::json::{self, Json};
use mixkvq::util::rng::Pcg32;

fn main() {
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let cc = meta.cache.clone(); // capacity 512, residual 128
    let weights = Weights::random(&mc, 7);
    let spec = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = cc.residual;
    let mut rng = Pcg32::seeded(11);
    let mut results = Vec::new();
    let mut entries = Vec::new();

    for qlen in [256usize, 512] {
        let driver = RefDriver::new(
            mc.clone(),
            cc.clone(),
            &weights,
            spec.clone(),
            Method::mixkvq("mix30"),
            r_limit,
        );
        // prompt sized so exactly `qlen` tokens land in the quantized window
        let t = qlen + r_limit;
        let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();
        let (cache, _) = driver.prefill(&prompt).unwrap();
        assert_eq!(cache.qlen, qlen, "prefill split drifted");

        let fused = bench(&format!("fused packed-code decode qlen={qlen}"), 300, 2500.0, || {
            std::hint::black_box(driver.decode_logits_fused(&cache, 17));
        });
        let legacy = bench(&format!("legacy dequant decode    qlen={qlen}"), 300, 2500.0, || {
            std::hint::black_box(driver.decode_logits_legacy(&cache, 17));
        });
        let speedup = legacy.median_ms / fused.median_ms;
        println!(
            "qlen={qlen}: fused {:.3} ms  legacy {:.3} ms  speedup {:.2}x{}",
            fused.median_ms,
            legacy.median_ms,
            speedup,
            if speedup < 3.0 { "  (below the 3x bar!)" } else { "" }
        );
        entries.push(json::obj(vec![
            ("qlen", json::num(qlen as f64)),
            ("fused_ms", json::num(fused.median_ms)),
            ("legacy_ms", json::num(legacy.median_ms)),
            ("speedup", json::num(speedup)),
        ]));
        results.push(fused);
        results.push(legacy);
    }

    println!("\n== ref_decode ==");
    for r in &results {
        println!("{}", r.report());
    }

    let report = json::obj(vec![
        ("bench", json::s("ref_decode")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_ref_decode.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_ref_decode.json");
}
