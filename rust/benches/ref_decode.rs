//! `cargo bench --bench ref_decode` — reference-path decode: fused
//! packed-code attention vs the legacy dequantize-then-attend path, plus
//! the paged-pool data points (decode streamed from a shared prewarmed
//! `KvPool` vs a private pool) and a peak-resident-bytes trajectory.
//!
//! Unlike the engine benches this needs **no artifacts** (random weights,
//! build-default shapes), so it always runs — on CI and on fresh checkouts —
//! and writes `BENCH_ref_decode.json` (throughput) and
//! `BENCH_paged_decode.json` (paged overhead + memory) so the perf
//! trajectory has data points. Two context lengths; the fused path must
//! stay ≥3× faster than legacy at qlen ≥ 256 (ISSUE 2 acceptance bar), and
//! the shared-pool path must not meaningfully lag the private one (pages
//! change provenance, not access cost).

use mixkvq::harness::refdriver::RefDriver;
use mixkvq::kvcache::pool::KvPool;
use mixkvq::model::config::Meta;
use mixkvq::model::weights::Weights;
use mixkvq::quant::methods::Method;
use mixkvq::util::bench::bench;
use mixkvq::util::json::{self, Json};
use mixkvq::util::rng::Pcg32;

fn main() {
    let meta = Meta::default_build();
    let mc = meta.model.clone();
    let cc = meta.cache.clone(); // capacity 512, residual 128
    let weights = Weights::random(&mc, 7);
    let spec = meta.variant("mix30").unwrap().layers.clone();
    let r_limit = cc.residual;
    let mut rng = Pcg32::seeded(11);
    let mut results = Vec::new();
    let mut entries = Vec::new();
    let mut paged_entries = Vec::new();

    for qlen in [256usize, 512] {
        let driver = RefDriver::new(
            mc.clone(),
            cc.clone(),
            &weights,
            spec.clone(),
            Method::mixkvq("mix30"),
            r_limit,
        );
        // prompt sized so exactly `qlen` tokens land in the quantized window
        let t = qlen + r_limit;
        let prompt: Vec<i32> = (0..t).map(|_| rng.range(1, 127) as i32).collect();
        let (cache, _) = driver.prefill(&prompt).unwrap();
        assert_eq!(cache.qlen, qlen, "prefill split drifted");

        // the same request served from a shared, bounded, prewarmed pool —
        // the serving storage configuration
        let pages = cache.leased_pages() + cache.pages_per_flush();
        let pool = KvPool::for_specs(spec.iter(), mc.d_head, cc.group, Some(pages));
        pool.prewarm(pages);
        let (pcache, _) = driver.prefill_pooled(&pool, &prompt).unwrap();
        assert_eq!(pcache.qlen, qlen);

        let fused = bench(&format!("fused packed-code decode qlen={qlen}"), 300, 2500.0, || {
            std::hint::black_box(driver.decode_logits_fused(&cache, 17));
        });
        let paged = bench(&format!("fused decode, shared pool qlen={qlen}"), 300, 2500.0, || {
            std::hint::black_box(driver.decode_logits_fused(&pcache, 17));
        });
        let legacy = bench(&format!("legacy dequant decode    qlen={qlen}"), 300, 2500.0, || {
            std::hint::black_box(driver.decode_logits_legacy(&cache, 17));
        });
        let speedup = legacy.median_ms / fused.median_ms;
        // memory trajectory: what this request actually holds (deployment
        // bytes) vs what worst-case preallocation would have pinned
        let peak_resident = pool.stats().high_water * pool.page_deploy_bytes();
        let worst_case = mixkvq::kvcache::accountant::MemoryAccountant::worst_case_request_bytes(
            &mc, &cc, &spec,
        );
        println!(
            "qlen={qlen}: fused {:.3} ms  paged {:.3} ms  legacy {:.3} ms  speedup {:.2}x{}",
            fused.median_ms,
            paged.median_ms,
            legacy.median_ms,
            speedup,
            if speedup < 3.0 { "  (below the 3x bar!)" } else { "" }
        );
        println!(
            "           peak resident {peak_resident} B (pages) vs {worst_case} B worst-case prealloc"
        );
        entries.push(json::obj(vec![
            ("qlen", json::num(qlen as f64)),
            ("fused_ms", json::num(fused.median_ms)),
            ("legacy_ms", json::num(legacy.median_ms)),
            ("speedup", json::num(speedup)),
        ]));
        paged_entries.push(json::obj(vec![
            ("qlen", json::num(qlen as f64)),
            ("paged_fused_ms", json::num(paged.median_ms)),
            ("private_fused_ms", json::num(fused.median_ms)),
            ("paged_overhead_pct", json::num(100.0 * (paged.median_ms / fused.median_ms - 1.0))),
            ("peak_resident_bytes", json::num(peak_resident as f64)),
            ("worst_case_prealloc_bytes", json::num(worst_case as f64)),
            ("pages_leased", json::num(pcache.leased_pages() as f64)),
        ]));
        results.push(fused);
        results.push(paged);
        results.push(legacy);
    }

    println!("\n== ref_decode ==");
    for r in &results {
        println!("{}", r.report());
    }

    let report = json::obj(vec![
        ("bench", json::s("ref_decode")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_ref_decode.json", report.print() + "\n").expect("write bench json");
    println!("wrote BENCH_ref_decode.json");

    let paged_report = json::obj(vec![
        ("bench", json::s("paged_decode")),
        ("variant", json::s("mix30")),
        ("entries", Json::Arr(paged_entries)),
    ]);
    std::fs::write("BENCH_paged_decode.json", paged_report.print() + "\n")
        .expect("write paged bench json");
    println!("wrote BENCH_paged_decode.json");
}
