//! `cargo bench --bench tab7_overhead` — regenerates: Table 7 time breakdown.
//! Set MIXKVQ_QUICK=1 for a reduced-size run.

use mixkvq::harness::experiments::{run, ExpCtx};

fn main() {
    let artifacts = std::path::PathBuf::from(
        std::env::var("MIXKVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let quick = std::env::var("MIXKVQ_QUICK").is_ok();
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP tab7_overhead: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let ctx = ExpCtx::new(&artifacts, quick);
    let t0 = std::time::Instant::now();
    match run(&ctx, "tab7") {
        Ok(table) => {
            println!("{}", table.print());
            println!("[tab7_overhead] regenerated in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("[tab7_overhead] FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}
