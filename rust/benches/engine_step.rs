//! End-to-end engine latency: prefill (both buckets) and the batched decode
//! step per variant — the L3 §Perf headline numbers.
//!
//!     make artifacts && cargo bench --bench engine_step

use mixkvq::coordinator::engine::Engine;
use mixkvq::harness::workloads;
use mixkvq::kvcache::cache::RequestCache;
use mixkvq::quant::methods::Method;
use mixkvq::util::bench::bench;
use mixkvq::util::rng::Pcg32;

fn main() {
    let artifacts = std::path::PathBuf::from(
        std::env::var("MIXKVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP engine_step: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let mut results = Vec::new();
    let mut rng = Pcg32::seeded(0);

    for method in [Method::bf16(), Method::mixkvq("mix225"), Method::mixkvq("mix30"), Method::kivi("kv2")] {
        let mut engine = Engine::new(&artifacts, method.clone(), 32).unwrap();
        let b = engine.meta.cache.decode_batch;

        // prefill latency (short + long bucket)
        for ctx_len in [100usize, 450] {
            let task = workloads::gen_passkey(&mut rng, ctx_len);
            if method.name == "bf16" || ctx_len == 450 {
                let name = format!("prefill t={} ({})", ctx_len, method.name);
                results.push(bench(&name, 30, 2000.0, || {
                    std::hint::black_box(engine.prefill(&task.prompt).unwrap());
                }));
            }
        }

        // full-batch decode step (8 live slots, quantized windows populated)
        let task = workloads::gen_passkey(&mut rng, 450);
        let pre = engine.prefill(&task.prompt).unwrap();
        let mut caches: Vec<RequestCache> =
            (0..b).map(|_| engine.quantize_prefill(&pre).unwrap()).collect();
        let name = format!("decode step B={b} qlen={} ({})", caches[0].qlen, method.name);
        results.push(bench(&name, 100, 3000.0, || {
            let mut slots: Vec<Option<(&mut RequestCache, i32)>> =
                caches.iter_mut().map(|c| Some((c, 17i32))).collect();
            std::hint::black_box(engine.decode_step(&mut slots).unwrap());
            // caches keep growing; reset residuals by rebuilding when near full
        }));
        // rebuild caches if residuals filled during the bench
        caches.clear();
    }

    println!("\n== engine_step ==");
    for r in &results {
        println!("{}", r.report());
    }
}
