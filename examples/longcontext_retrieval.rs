//! Long-context retrieval across methods and bit budgets — the LongBench
//! analogue (Table 4) as a runnable scenario: a passkey buried in ~460
//! tokens of filler must survive 2-bit cache quantization of the prompt.
//!
//!     make artifacts && cargo run --release --example longcontext_retrieval

use anyhow::Result;
use mixkvq::coordinator::engine::Engine;
use mixkvq::harness::accuracy;
use mixkvq::harness::workloads::{suite, TaskKind};
use mixkvq::quant::methods::Method;
use mixkvq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.usize_or("tasks", 24)?;
    let tasks = suite(TaskKind::Passkey, n, 11, true);
    let lookups = suite(TaskKind::KvLookup, n, 11, true);
    println!(
        "long-context retrieval: {} passkey tasks (~460-token contexts), {} kv-lookups\n",
        tasks.len(),
        lookups.len()
    );
    println!(
        "{:<16} {:>9} {:>12} {:>12} {:>14}",
        "method", "key-bits", "passkey %", "kvlookup %", "cache vs fp16"
    );
    let mut engine = Engine::new(&artifacts, Method::bf16(), 128)?;
    for method in [
        Method::bf16(),
        Method::kivi("kv4"),
        Method::kivi("kv2"),
        Method::kvquant("kv2"),
        Method::rotatekv("kv2"),
        Method::skvq("kv2"),
        Method::mixkvq("mix225"),
        Method::mixkvq("mix30"),
    ] {
        engine.set_method(method.clone())?;
        let rep_p = accuracy::evaluate(&mut engine, &tasks)?;
        let rep_k = accuracy::evaluate(&mut engine, &lookups)?;
        // measure real cache bytes on one long request
        let pre = engine.prefill(&tasks[0].prompt)?;
        let cache = engine.admit_prefill(&pre)?;
        let rep = mixkvq::kvcache::accountant::report(&cache);
        println!(
            "{:<16} {:>9.2} {:>12.1} {:>12.1} {:>13.2}x",
            method.name,
            engine.variant.key_bits,
            100.0 * rep_p.task_acc(),
            100.0 * rep_k.task_acc(),
            rep.ratio
        );
    }
    println!("\nExpected shape (paper Table 4): MixKVQ ≈ BF16 at ~4x less cache; fixed 2-bit degrades.");
    Ok(())
}
