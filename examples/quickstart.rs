//! Quickstart: quantize a toy KV window with MixKVQ and every baseline,
//! inspect error and memory — no artifacts needed (pure library use).
//!
//!     cargo run --release --example quickstart

use mixkvq::kvcache::accountant::{bytes_per_token, effective_bits, fp16_bytes_per_token};
use mixkvq::quant::methods::Method;
use mixkvq::quant::window::{
    dequantize_key_window, plan_order, quantize_key_window, quantize_value_window, TierSpec,
};
use mixkvq::util::rng::Pcg32;
use mixkvq::util::stats::rel_l2;

fn main() {
    let (t, d, g) = (128usize, 32usize, 32usize);
    let mut rng = Pcg32::seeded(0);

    // A key window with two outlier channels (the Fig. 2 phenomenon) whose
    // corresponding query magnitudes differ: channel 5 is hot for queries,
    // channel 23 is not — exactly the case MixKVQ's salience score decides.
    let mut k: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let mut importance = vec![0.3f32; d];
    for tok in 0..t {
        k[tok * d + 5] *= 10.0;
        k[tok * d + 23] *= 10.0;
    }
    importance[5] = 3.0; // query-relevant outlier channel
    importance[23] = 0.02; // query-irrelevant outlier channel

    // Query vector proportional to importance (what attention would see).
    let q: Vec<f32> = importance.iter().map(|&x| x).collect();
    let exact: Vec<f32> = (0..t)
        .map(|tok| (0..d).map(|ch| q[ch] * k[tok * d + ch]).sum())
        .collect();

    println!("MixKVQ quickstart — 3-tier key quantization on a {t}x{d} window\n");
    println!(
        "{:<16} {:>9} {:>12} {:>14} {:>12}",
        "method", "key-bits", "B/token", "score rel-L2", "vs fp16"
    );

    let spec = TierSpec { n16: 2, n4: 2, n2: 28, v_bits: 2 };
    for method in [
        Method::bf16(),
        Method::kivi("kv2"),
        Method::kvquant("kv2"),
        Method::skvq("kv2"),
        Method::rotatekv("kv2"),
        Method::mixkvq_error_only("mix30"),
        Method::mixkvq("mix30"),
    ] {
        let use_spec = match method.variant.as_str() {
            "bf16" => TierSpec { n16: d, n4: 0, n2: 0, v_bits: 16 },
            "kv2" => TierSpec { n16: 0, n4: 0, n2: d, v_bits: 2 },
            _ => spec,
        };
        // rotate if the method asks for it
        let rot = method.rotation(d);
        let mut krot = k.clone();
        if method.rotate {
            mixkvq::quant::rotation::rotate_rows(&mut krot, t, d, &rot);
        }
        let order = plan_order(method.ordering, &importance, &krot, t, d);
        let w = quantize_key_window(&krot, t, d, use_spec, &order, method.key_opts(g));
        let back_rot = dequantize_key_window(&w, d, g);
        // scores in rotated space: (q·R)·(k̃)ᵀ
        let mut qr = vec![0f32; d];
        mixkvq::quant::rotation::rotate_vec(&q, &rot, &mut qr);
        let approx: Vec<f32> = (0..t)
            .map(|tok| (0..d).map(|ch| qr[ch] * back_rot[tok * d + ch]).sum())
            .collect();
        let bpt = bytes_per_token(&use_spec, d, g);
        println!(
            "{:<16} {:>9.2} {:>12.1} {:>14.4} {:>11.2}x",
            method.name,
            effective_bits(&use_spec, d, g) * 2.0 * d as f64 / (2.0 * d as f64), // = eff bits
            bpt,
            rel_l2(&approx, &exact),
            fp16_bytes_per_token(d) / bpt,
        );
    }

    // Value side: per-token 2-bit is enough (Table 2's finding).
    let v: Vec<f32> = (0..t * d).map(|_| rng.normal()).collect();
    let vw = quantize_value_window(&v, t, d, 2, g);
    let vback = mixkvq::quant::window::dequantize_value_window(&vw, d, g);
    println!(
        "\nvalue cache @2-bit per-token: rel-L2 {:.4} (uniform error, no outliers — Fig. 2 right)",
        rel_l2(&vback, &v)
    );
    println!(
        "\nTakeaway: MixKVQ protects the query-relevant outlier channel (5) in BF16\n\
         and lets the query-irrelevant one (23) stay 2-bit; error-only protects\n\
         both outliers and wastes budget, fixed 2-bit protects neither."
    );
}
