//! End-to-end serving driver (the DESIGN.md validation workload): load the
//! trained MiniReasoner artifacts, serve a batched mixed trace of reasoning
//! and retrieval requests through the full L3→L2→L1 stack, and report
//! accuracy, latency, throughput, and memory vs the BF16 baseline — then
//! demonstrate the session API serving two tenants with *different*
//! `MethodSpec`s concurrently through one server.
//!
//!     make artifacts && cargo run --release --example serve_reasoning
//!     (options: --method mixkvq-mix30 --requests 24 --artifacts <dir>)

use anyhow::{bail, Result};
use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::events::{by_request, validate_stream};
use mixkvq::coordinator::metrics::breakdown;
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::Request;
use mixkvq::harness::accuracy;
use mixkvq::harness::workloads::{suite, TaskKind};
use mixkvq::model::sampler::Sampling;
use mixkvq::quant::methods::{Method, MethodSpec};
use mixkvq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.usize_or("requests", 24)?;
    let method_name = args.get_or("method", "mixkvq-mix30");
    let methods = ["bf16", method_name.as_str()]
        .iter()
        .map(|m| Method::by_name(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}")))
        .collect::<Result<Vec<_>>>()?;

    for method in methods {
        println!("\n===== {} =====", method.name);
        let mut engine = Engine::new(&artifacts, method.clone(), 128)?;

        // 1) task accuracy through the quantized cache (teacher-forced)
        for kind in [TaskKind::Chain, TaskKind::Passkey, TaskKind::KvLookup, TaskKind::Copy] {
            let tasks = suite(kind, 16, 7, false);
            let rep = accuracy::evaluate(&mut engine, &tasks)?;
            println!(
                "  {:<9} task-acc {:>5.1}%  answer-acc {:>5.1}%",
                kind.name(),
                100.0 * rep.task_acc(),
                100.0 * rep.token_acc()
            );
        }

        // 2) generative serving: mixed reasoning trace, batched (the
        //    Server::run shim over the session frontend)
        engine.timers = Default::default();
        let mut server = Server::new(engine, ServerConfig::default());
        let completed = server.run(trace(n, None, None))?;
        if completed.len() != n {
            bail!("served {} of {n} requests", completed.len());
        }
        println!("  serving: {}", server.metrics.summary());
        let b = breakdown(&server.engine.timers);
        println!(
            "  breakdown: model {:.1}% | quantize {:.1}% | assemble {:.1}% (quant events/step {:.1}%)",
            b.model_exec_pct, b.quantize_pct, b.assemble_pct, b.quantize_call_rate_pct
        );
    }

    // 3) per-request routing: two tenants with different precision policies
    //    share one server — tenant A on the default (the quantized method),
    //    tenant B pinned to bf16 — batched per decode variant each tick.
    let spec: MethodSpec = method_name
        .parse()
        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
    let other = if spec == MethodSpec::Bf16 {
        MethodSpec::MixKvq { op: mixkvq::quant::methods::MixOp::Mix30 }
    } else {
        MethodSpec::Bf16
    };
    println!("\n===== mixed tenants: {spec} + {other} on one server =====");
    let engine = Engine::new(&artifacts, spec.build(), 128)?;
    let mut server = Server::new(engine, ServerConfig::default());
    let n_mixed = 8.min(n.max(2));
    server.metrics.start();
    let ids: Vec<u64> = trace(n_mixed, Some(other), Some(spec))
        .into_iter()
        .map(|r| server.submit(r))
        .collect::<Result<_>>()?;
    // first tick admits both tenants — verify they run concurrently.
    // (Count via the batcher, not poll: the first poll observing a
    // terminal request consumes its full record — poll is not a passive
    // status probe any more.)
    server.tick()?;
    let live = server.batcher.live();
    println!("  after tick 1: {live} sessions live concurrently");
    while server.has_work() {
        server.tick()?;
    }
    server.metrics.stop();
    let events = server.drain_events();
    for (id, stream) in by_request(&events) {
        let max_new = 48;
        if let Err(e) = validate_stream(&stream, max_new) {
            bail!("request {id}: malformed event stream: {e}");
        }
    }
    let by_method = server.metrics.completed_by_method();
    for (m, k) in &by_method {
        println!("  {m}: {k} requests completed");
    }
    if by_method.len() < 2 {
        bail!("expected two distinct methods to complete on one server");
    }
    println!("  all {} event streams well-formed", ids.len());
    println!("  serving: {}", server.metrics.summary());
    Ok(())
}

/// A small mixed reasoning/retrieval trace; odd requests get `odd_method`,
/// even requests `even_method` (None = server default).
fn trace(n: usize, odd_method: Option<MethodSpec>, even_method: Option<MethodSpec>) -> Vec<Request> {
    let mut rng = mixkvq::util::rng::Pcg32::seeded(3);
    (0..n)
        .map(|i| {
            let task = match i % 3 {
                0 => mixkvq::harness::workloads::gen_chain(&mut rng, 8),
                1 => mixkvq::harness::workloads::gen_passkey(&mut rng, 200),
                _ => mixkvq::harness::workloads::gen_kvlookup(&mut rng, 10),
            };
            Request {
                id: i as u64,
                prompt: task.prompt,
                max_new_tokens: 48,
                sampling: Sampling::Greedy,
                method: if i % 2 == 1 { odd_method } else { even_method },
                tenant: (i % 2) as u32,
            }
        })
        .collect()
}
