//! End-to-end serving driver (the DESIGN.md validation workload): load the
//! trained MiniReasoner artifacts, serve a batched mixed trace of reasoning
//! and retrieval requests through the full L3→L2→L1 stack, and report
//! accuracy, latency, throughput, and memory vs the BF16 baseline.
//!
//!     make artifacts && cargo run --release --example serve_reasoning
//!     (options: --method mixkvq-mix30 --requests 24 --artifacts <dir>)

use anyhow::{bail, Result};
use mixkvq::coordinator::engine::Engine;
use mixkvq::coordinator::metrics::breakdown;
use mixkvq::coordinator::router::{Server, ServerConfig};
use mixkvq::coordinator::session::Request;
use mixkvq::harness::accuracy;
use mixkvq::harness::workloads::{suite, TaskKind};
use mixkvq::model::sampler::Sampling;
use mixkvq::quant::methods::Method;
use mixkvq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.usize_or("requests", 24)?;
    let methods = ["bf16", args.get_or("method", "mixkvq-mix30").as_str()]
        .iter()
        .map(|m| Method::by_name(m).ok_or_else(|| anyhow::anyhow!("unknown method {m}")))
        .collect::<Result<Vec<_>>>()?;

    for method in methods {
        println!("\n===== {} =====", method.name);
        let mut engine = Engine::new(&artifacts, method.clone(), 128)?;

        // 1) task accuracy through the quantized cache (teacher-forced)
        for kind in [TaskKind::Chain, TaskKind::Passkey, TaskKind::KvLookup, TaskKind::Copy] {
            let tasks = suite(kind, 16, 7, false);
            let rep = accuracy::evaluate(&mut engine, &tasks)?;
            println!(
                "  {:<9} task-acc {:>5.1}%  answer-acc {:>5.1}%",
                kind.name(),
                100.0 * rep.task_acc(),
                100.0 * rep.token_acc()
            );
        }

        // 2) generative serving: mixed reasoning trace, batched
        engine.timers = Default::default();
        let mut server = Server::new(engine, ServerConfig::default());
        let mut reqs = Vec::new();
        let mut rng = mixkvq::util::rng::Pcg32::seeded(3);
        for i in 0..n {
            let task = match i % 3 {
                0 => mixkvq::harness::workloads::gen_chain(&mut rng, 8),
                1 => mixkvq::harness::workloads::gen_passkey(&mut rng, 200),
                _ => mixkvq::harness::workloads::gen_kvlookup(&mut rng, 10),
            };
            reqs.push(Request {
                id: i as u64,
                prompt: task.prompt,
                max_new_tokens: 48,
                sampling: Sampling::Greedy,
            });
        }
        let completed = server.run(reqs)?;
        if completed.len() != n {
            bail!("served {} of {n} requests", completed.len());
        }
        println!("  serving: {}", server.metrics.summary());
        let b = breakdown(&server.engine.timers);
        println!(
            "  breakdown: model {:.1}% | quantize {:.1}% | assemble {:.1}% (quant events/step {:.1}%)",
            b.model_exec_pct, b.quantize_pct, b.assemble_pct, b.quantize_call_rate_pct
        );
    }
    Ok(())
}
