//! Channel-statistics explorer: dumps the raw data behind Figs. 2, 3 and 6
//! from a real prefilled prompt — per-channel error, I/S correlation, and
//! the salience-vs-scale tier decisions.
//!
//!     make artifacts && cargo run --release --example quant_explorer

use anyhow::Result;
use mixkvq::coordinator::engine::Engine;
use mixkvq::harness::experiments::{ExpCtx, run};
use mixkvq::quant::methods::Method;
use mixkvq::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let ctx = ExpCtx::new(&artifacts, true);

    for id in ["fig2", "fig3", "fig6"] {
        println!("{}", run(&ctx, id)?.print());
    }

    // bonus: live salience snapshot after some decoding
    let mut engine = Engine::new(&artifacts, Method::mixkvq("mix30"), 32)?;
    let mut rng = mixkvq::util::rng::Pcg32::seeded(2);
    let task = mixkvq::harness::workloads::gen_passkey(&mut rng, 150);
    let pre = engine.prefill(&task.prompt)?;
    let cache = engine.admit_prefill(&pre)?;
    println!("== live channel plan (layer 0) ==");
    for h in 0..engine.meta.model.n_kv_heads {
        let head = &cache.heads[0][h];
        let spec = head.spec;
        println!(
            "head {h}: BF16 tier -> channels {:?}, UINT4 tier -> {:?}",
            &head.idx[..spec.n16],
            &head.idx[spec.n16..spec.n16 + spec.n4],
        );
    }
    Ok(())
}
