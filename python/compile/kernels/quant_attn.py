"""L1 Pallas kernels: fused dequant + attention over the packed KV cache.

The paper's CUDA hot spot is "dequantize K on the fly, right before QK^T".
On the Pallas/TPU model this becomes (DESIGN.md §Hardware-Adaptation):

* packed u8 key blocks + per-channel scale/zero vectors are streamed
  HBM -> VMEM via BlockSpecs over the cache-length axis C;
* nibble/crumb unpacking happens in-register (shift + mask on the VPU);
* the tier matmuls target the MXU (f32 here; bf16 on real TPU).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
compiles like any other op (see /opt/xla-example/README.md).

VMEM budget at the default shapes (C=512, BLOCK_C=128, d_head=32, Hq=4,
G=32): packed K block <= 128x16 B = 2 KiB, scales 4x32x4 B = 0.5 KiB,
q tiles < 1 KiB, fp16 tier 128x n16 x4 B <= 16 KiB, out tile 4x128x4 B =
2 KiB — orders of magnitude under the 16 MiB VMEM ceiling, leaving room to
scale C to 64K tokens (128 KiB/block) before re-tiling is needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_C = 128


def _unpack_u4(p):
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)


def _unpack_u2(p):
    parts = [(p >> (2 * k)) & 0x3 for k in range(4)]
    return jnp.stack(parts, axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 4)


def _dequant_block(packed, scale, zero, group: int, bits: int):
    """packed: [bc, n*bits/8]; scale/zero: [bc/G, n] -> [bc, n] f32."""
    q = _unpack_u4(packed) if bits == 4 else _unpack_u2(packed)
    s = jnp.repeat(scale, group, axis=0)
    z = jnp.repeat(zero, group, axis=0)
    return q.astype(jnp.float32) * s + z


def mixed_qk_scores(q16, q4, q2, k16, k4p, k4s, k4z, k2p, k2s, k2z,
                    *, group: int, block_c: int = BLOCK_C):
    """Pre-softmax scores [Hq, C] of per-tier queries vs 3-tier packed keys.

    Empty tiers (n = 0) are elided from the kernel signature so the lowered
    HLO never carries zero-sized operands.
    """
    hq = q16.shape[0]
    c = max(k16.shape[0], k4p.shape[0], k2p.shape[0])
    n16, n4, n2 = k16.shape[1], k4s.shape[-1] if k4p.size else 0, k2s.shape[-1] if k2p.size else 0
    if k4p.size == 0:
        n4 = 0
    if k2p.size == 0:
        n2 = 0
    gpb = block_c // group  # scale groups per block

    args, in_specs, kinds = [], [], []

    def add(arr, spec, kind):
        args.append(arr)
        in_specs.append(spec)
        kinds.append(kind)

    row = lambda n: pl.BlockSpec((hq, n), lambda i: (0, 0))
    blk = lambda n: pl.BlockSpec((block_c, n), lambda i: (i, 0))
    grp = lambda n: pl.BlockSpec((gpb, n), lambda i: (i, 0))

    if n16:
        add(q16, row(n16), "q16")
        add(k16, blk(n16), "k16")
    if n4:
        add(q4, row(n4), "q4")
        add(k4p, blk(n4 // 2), "k4p")
        add(k4s, grp(n4), "k4s")
        add(k4z, grp(n4), "k4z")
    if n2:
        add(q2, row(n2), "q2")
        add(k2p, blk(n2 // 4), "k2p")
        add(k2s, grp(n2), "k2s")
        add(k2z, grp(n2), "k2z")

    def kernel(*refs):
        ins = dict(zip(kinds, refs[:-1]))
        out_ref = refs[-1]
        acc = jnp.zeros(out_ref.shape, jnp.float32)
        if "k16" in ins:
            acc += ins["q16"][...] @ ins["k16"][...].T
        if "k4p" in ins:
            k4 = _dequant_block(ins["k4p"][...], ins["k4s"][...], ins["k4z"][...], group, 4)
            acc += ins["q4"][...] @ k4.T
        if "k2p" in ins:
            k2 = _dequant_block(ins["k2p"][...], ins["k2s"][...], ins["k2z"][...], group, 2)
            acc += ins["q2"][...] @ k2.T
        out_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(c // block_c,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((hq, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((hq, c), jnp.float32),
        interpret=True,
    )(*args)


def quant_av(probs, vp, vs, vz, *, group: int, bits: int, block_c: int = BLOCK_C):
    """probs [Hq, C] x packed per-token values [C, D*bits/8] -> [Hq, D].

    Accumulates across C-blocks into the output tile (classic flash-style
    running sum; the softmax normalizer is handled by the caller).
    """
    hq, c = probs.shape
    d = vs.shape[-1] * group

    def kernel(p_ref, vp_ref, vs_ref, vz_ref, out_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _():
            out_ref[...] = jnp.zeros(out_ref.shape, jnp.float32)

        q = _unpack_u4(vp_ref[...]) if bits == 4 else _unpack_u2(vp_ref[...])
        qg = q.reshape(block_c, d // group, group).astype(jnp.float32)
        v = (qg * vs_ref[...][..., None] + vz_ref[...][..., None]).reshape(block_c, d)
        out_ref[...] += p_ref[...] @ v

    return pl.pallas_call(
        kernel,
        grid=(c // block_c,),
        in_specs=[
            pl.BlockSpec((hq, block_c), lambda i: (0, i)),
            pl.BlockSpec((block_c, d * bits // 8), lambda i: (i, 0)),
            pl.BlockSpec((block_c, d // group), lambda i: (i, 0)),
            pl.BlockSpec((block_c, d // group), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((hq, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, d), jnp.float32),
        interpret=True,
    )(probs, vp, vs, vz)


@functools.partial(jax.jit, static_argnames=("group",))
def jit_mixed_qk_scores(q16, q4, q2, k16, k4p, k4s, k4z, k2p, k2s, k2z, group):
    return mixed_qk_scores(q16, q4, q2, k16, k4p, k4s, k4z, k2p, k2s, k2z, group=group)
