"""Asymmetric group quantization + u2/u4 bit packing (jnp, build-time).

Mirrors rust/src/quant/{asym,packing}.rs bit-for-bit:

* codes: ``q = clip(round((x - z) / s), 0, 2^B - 1)`` with ``z = min``,
  ``s = (max - min) / (2^B - 1)`` (Eq. 2–3 of the paper).
* u4 packing: channel pair (2j, 2j+1) -> byte j, low nibble = channel 2j.
* u2 packing: channel quad (4j..4j+3) -> byte j, bits (2k..2k+1) = 4j+k.
"""

import jax.numpy as jnp

EPS = 1e-8


def qmax(bits: int) -> int:
    return (1 << bits) - 1


def quant_params(x, axis, bits: int):
    """scale/zero over `axis` (kept as size-1 dims for broadcasting)."""
    lo = jnp.min(x, axis=axis, keepdims=True)
    hi = jnp.max(x, axis=axis, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax(bits), EPS)
    return scale, lo


def quantize(x, scale, zero, bits: int):
    q = jnp.round((x - zero) / scale)
    return jnp.clip(q, 0, qmax(bits)).astype(jnp.uint8)


def dequantize(q, scale, zero):
    return q.astype(jnp.float32) * scale + zero


# -- packing ----------------------------------------------------------------

def pack_u4(q):
    """[..., 2n] u8 codes in 0..15 -> [..., n] bytes."""
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_u4(p):
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    return jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)


def pack_u2(q):
    """[..., 4n] u8 codes in 0..3 -> [..., n] bytes."""
    b = q[..., 0::4] | (q[..., 1::4] << 2) | (q[..., 2::4] << 4) | (q[..., 3::4] << 6)
    return b.astype(jnp.uint8)


def unpack_u2(p):
    parts = [(p >> (2 * k)) & 0x3 for k in range(4)]
    return jnp.stack(parts, axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 4)


def pack(q, bits: int):
    if bits == 4:
        return pack_u4(q)
    if bits == 2:
        return pack_u2(q)
    raise ValueError(bits)


def unpack(p, bits: int):
    if bits == 4:
        return unpack_u4(p)
    if bits == 2:
        return unpack_u2(p)
    raise ValueError(bits)


# -- cache-shaped helpers ----------------------------------------------------

def quantize_key_channelwise(k, group: int, bits: int):
    """Per-channel key quant, grouped along tokens (KIVI layout).

    k: [T, D] -> packed [T, D*bits//8], scale/zero [T//G, D].
    """
    t, d = k.shape
    kg = k.reshape(t // group, group, d)
    scale, zero = quant_params(kg, axis=1, bits=bits)          # [T/G, 1, D]
    q = quantize(kg, scale, zero, bits).reshape(t, d)
    return pack(q, bits), scale[:, 0, :], zero[:, 0, :]


def dequantize_key_channelwise(packed, scale, zero, group: int, bits: int):
    q = unpack(packed, bits)                                   # [T, D]
    t, d = q.shape
    qg = q.reshape(t // group, group, d).astype(jnp.float32)
    x = qg * scale[:, None, :] + zero[:, None, :]
    return x.reshape(t, d)


def quantize_value_tokenwise(v, group: int, bits: int):
    """Per-token value quant, grouped along channels.

    v: [T, D] -> packed [T, D*bits//8], scale/zero [T, D//G].
    """
    t, d = v.shape
    vg = v.reshape(t, d // group, group)
    scale, zero = quant_params(vg, axis=2, bits=bits)          # [T, D/G, 1]
    q = quantize(vg, scale, zero, bits).reshape(t, d)
    return pack(q, bits), scale[..., 0], zero[..., 0]


def dequantize_value_tokenwise(packed, scale, zero, group: int, bits: int):
    q = unpack(packed, bits)
    t, d = q.shape
    qg = q.reshape(t, d // group, group).astype(jnp.float32)
    x = qg * scale[..., None] + zero[..., None]
    return x.reshape(t, d)
