"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Everything here is dense, unfused, and obviously-correct; pytest asserts the
Pallas kernels in quant_attn.py match these to float tolerance.
"""

import jax.numpy as jnp

from . import quant as Q


def ref_mixed_scores(q16, q4, q2, k16, k4_packed, k4_scale, k4_zero,
                     k2_packed, k2_scale, k2_zero, group: int):
    """Pre-softmax scores of queries against a 3-tier quantized key cache.

    q16/q4/q2: [Hq, n16/n4/n2] query channels pre-gathered per tier.
    k16: [C, n16] full-precision tier.
    k4_packed: [C, n4/2] u8; k4_scale/zero: [C/G, n4]. Likewise for k2.
    Returns [Hq, C].
    """
    hq = q16.shape[0]
    c = max(k16.shape[0], k4_packed.shape[0], k2_packed.shape[0])
    s = jnp.zeros((hq, c), jnp.float32)
    if k16.size:
        s = s + q16 @ k16.T
    if k4_packed.size:
        k4 = Q.dequantize_key_channelwise(k4_packed, k4_scale, k4_zero, group, 4)
        s = s + q4 @ k4.T
    if k2_packed.size:
        k2 = Q.dequantize_key_channelwise(k2_packed, k2_scale, k2_zero, group, 2)
        s = s + q2 @ k2.T
    return s


def ref_quant_av(probs, v_packed, v_scale, v_zero, group: int, bits: int):
    """probs: [Hq, C]; quantized per-token values -> [Hq, D]."""
    v = Q.dequantize_value_tokenwise(v_packed, v_scale, v_zero, group, bits)
    return probs @ v


def ref_attention(q, k, v, mask=None, scale=None):
    """Vanilla single-step attention. q: [Hq, D]; k/v: [T, D]; mask: [T]."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    s = (q @ k.T) * scale
    if mask is not None:
        s = jnp.where(mask[None, :], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v
