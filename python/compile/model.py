"""L2: MiniReasoner — GQA + RoPE decoder transformer over a quantized cache.

Three entry points, all lowered to HLO text by ``aot.py``:

* ``forward_train``  — full-precision causal LM forward (training / PPL).
* ``make_prefill``   — one prompt -> last-position logits + full-precision
                       K/V (post-RoPE) + per-channel |Q| statistics (the
                       I_d accumulator seed, Eq. 6).
* ``make_decode``    — one batched token step over a 3-tier quantized key
                       cache + 2/4-bit value cache + full-precision residual
                       buffer (Fig. 4 of the paper), calling the L1 Pallas
                       kernels for the packed portion.

The quantized tiers live in a *rotated* channel space (``rot`` input):
identity for MixKVQ/KIVI/KVQuant/SKVQ, a scaled Hadamard for RotateKV.
Scores against the quantized window therefore use ``q @ rot``, while the
residual buffer and the current token stay in the unrotated space.

Input/output orderings are defined by ``decode_input_manifest`` /
``prefill_input_manifest`` and serialized to artifacts/<name>.inputs.json,
which the Rust runtime treats as the ABI.
"""

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import CacheConfig, ModelConfig, QuantVariant
from .kernels.quant_attn import mixed_qk_scores, quant_av

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_spec(mc: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) ordering — the weights.bin ABI."""
    spec = [("embed", (mc.vocab, mc.d_model))]
    hq, hkv, dh = mc.n_q_heads, mc.n_kv_heads, mc.d_head
    for l in range(mc.n_layers):
        spec += [
            (f"l{l}.ln1", (mc.d_model,)),
            (f"l{l}.wq", (mc.d_model, hq * dh)),
            (f"l{l}.wk", (mc.d_model, hkv * dh)),
            (f"l{l}.wv", (mc.d_model, hkv * dh)),
            (f"l{l}.wo", (hq * dh, mc.d_model)),
            (f"l{l}.ln2", (mc.d_model,)),
            (f"l{l}.w1", (mc.d_model, mc.d_ff)),
            (f"l{l}.w2", (mc.d_ff, mc.d_model)),
        ]
    spec.append(("ln_f", (mc.d_model,)))
    return spec


def init_params(mc: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_spec(mc):
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            w = rng.normal(0.0, fan_in ** -0.5, size=shape).astype(np.float32)
            params[name] = jnp.asarray(w)
    return params


def flatten_params(params: Dict[str, jax.Array], mc: ModelConfig) -> List[jax.Array]:
    return [params[name] for name, _ in param_spec(mc)]


def unflatten_params(flat: List[jax.Array], mc: ModelConfig) -> Dict[str, jax.Array]:
    return {name: a for (name, _), a in zip(param_spec(mc), flat)}


# ---------------------------------------------------------------------------
# Primitive blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope_tables(positions, d_head: int, theta: float):
    """cos/sin [..., d_head/2] for integer positions."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Half-rotation convention: (x1, x2) -> (x1 c - x2 s, x2 c + x1 s)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def mlp(x, w1, w2):
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# Full-precision causal forward (training / perplexity / prefill)
# ---------------------------------------------------------------------------

def forward_train(params, tokens, mc: ModelConfig):
    """tokens: i32[B, T] -> logits f32[B, T, V]. Also returns (k, v, qabs)."""
    b, t = tokens.shape
    hq, hkv, dh, qpk = mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv
    h = params["embed"][tokens]
    pos = jnp.arange(t)
    cos, sin = rope_tables(pos, dh, mc.rope_theta)          # [T, dh/2]
    causal = jnp.tril(jnp.ones((t, t), bool))
    ks, vs, qabss = [], [], []
    for l in range(mc.n_layers):
        x = rmsnorm(h, params[f"l{l}.ln1"], mc.rmsnorm_eps)
        q = (x @ params[f"l{l}.wq"]).reshape(b, t, hq, dh)
        k = (x @ params[f"l{l}.wk"]).reshape(b, t, hkv, dh)
        v = (x @ params[f"l{l}.wv"]).reshape(b, t, hkv, dh)
        q = apply_rope(q, cos[None, :, None], sin[None, :, None])
        k = apply_rope(k, cos[None, :, None], sin[None, :, None])
        ks.append(k)
        vs.append(v)
        qabss.append(jnp.mean(jnp.abs(q.reshape(b, t, hkv, qpk, dh)), axis=3))
        # GQA scores: [B, Hkv, qpk, T, T]
        qg = q.reshape(b, t, hkv, qpk, dh).transpose(0, 2, 3, 1, 4)
        kg = k.transpose(0, 2, 1, 3)
        s = jnp.einsum("bhgtd,bhsd->bhgts", qg, kg) / jnp.sqrt(dh)
        s = jnp.where(causal[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgts,bhsd->bhgtd", p, v.transpose(0, 2, 1, 3))
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, t, hq * dh)
        h = h + o @ params[f"l{l}.wo"]
        x = rmsnorm(h, params[f"l{l}.ln2"], mc.rmsnorm_eps)
        h = h + mlp(x, params[f"l{l}.w1"], params[f"l{l}.w2"])
    h = rmsnorm(h, params["ln_f"], mc.rmsnorm_eps)
    logits = h @ params["embed"].T
    aux = (jnp.stack(ks), jnp.stack(vs), jnp.stack(qabss))  # [L,B,T,Hkv,dh]x2, [L,B,T,Hkv,dh]
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def make_prefill(mc: ModelConfig, t: int):
    """Returns fn(*flat_params, tokens i32[T], length i32) -> tuple."""
    n_params = len(param_spec(mc))

    def prefill(*args):
        flat, tokens, length = list(args[:n_params]), args[n_params], args[n_params + 1]
        params = unflatten_params(flat, mc)
        logits, (k, v, qabs) = forward_train(params, tokens[None], mc)
        valid = (jnp.arange(t) < length)[None, :, None, None]
        qabs_mean = jnp.sum(jnp.where(valid, qabs, 0.0), axis=(1, 2)) / jnp.maximum(
            length.astype(jnp.float32), 1.0
        )                                                    # [L, Hkv, dh]
        last = logits[0, jnp.maximum(length - 1, 0)]         # [V]
        # k/v: [L, 1, T, Hkv, dh] -> [L, Hkv, T, dh]
        kk = k[:, 0].transpose(0, 2, 1, 3)
        vv = v[:, 0].transpose(0, 2, 1, 3)
        return (last, kk, vv, qabs_mean)

    return prefill


def prefill_input_manifest(mc: ModelConfig, t: int) -> List[Tuple[str, Tuple[int, ...], str]]:
    m = [(n, s, "f32") for n, s in param_spec(mc)]
    m += [("tokens", (t,), "i32"), ("length", (), "i32")]
    return m


# ---------------------------------------------------------------------------
# Decode over the quantized cache
# ---------------------------------------------------------------------------

def decode_input_manifest(mc: ModelConfig, cc: CacheConfig, var: QuantVariant):
    """(name, shape, dtype) in positional order — the rust<->HLO ABI."""
    b, c, r, g = cc.decode_batch, cc.capacity, cc.residual, cc.group
    hkv, dh = mc.n_kv_heads, mc.d_head
    cg = c // g
    m = [(n, s, "f32") for n, s in param_spec(mc)]
    m += [
        ("token", (b,), "i32"),
        ("pos", (b,), "i32"),
        ("qlen", (b,), "i32"),
        ("rlen", (b,), "i32"),
        ("rot", (dh, dh), "f32"),
    ]
    for l, (n16, n4, n2, vb) in enumerate(var.layers):
        if n16:
            m += [(f"l{l}.idx16", (b, hkv, n16), "i32"),
                  (f"l{l}.k16", (b, hkv, c, n16), "f32")]
        if n4:
            m += [(f"l{l}.idx4", (b, hkv, n4), "i32"),
                  (f"l{l}.k4p", (b, hkv, c, n4 // 2), "u8"),
                  (f"l{l}.k4s", (b, hkv, cg, n4), "f32"),
                  (f"l{l}.k4z", (b, hkv, cg, n4), "f32")]
        if n2:
            m += [(f"l{l}.idx2", (b, hkv, n2), "i32"),
                  (f"l{l}.k2p", (b, hkv, c, n2 // 4), "u8"),
                  (f"l{l}.k2s", (b, hkv, cg, n2), "f32"),
                  (f"l{l}.k2z", (b, hkv, cg, n2), "f32")]
        if vb == 16:
            m += [(f"l{l}.vfull", (b, hkv, c, dh), "f32")]
        else:
            m += [(f"l{l}.vp", (b, hkv, c, dh * vb // 8), "u8"),
                  (f"l{l}.vs", (b, hkv, c, dh // g), "f32"),
                  (f"l{l}.vz", (b, hkv, c, dh // g), "f32")]
        m += [(f"l{l}.kres", (b, hkv, r, dh), "f32"),
              (f"l{l}.vres", (b, hkv, r, dh), "f32")]
    return m


def make_decode(mc: ModelConfig, cc: CacheConfig, var: QuantVariant):
    """Batched single-token decode step. See decode_input_manifest for ABI.

    Outputs: (logits [B,V], knew [L,B,Hkv,dh], vnew [L,B,Hkv,dh],
              qabs [L,B,Hkv,dh]).
    """
    b, c, r, g = cc.decode_batch, cc.capacity, cc.residual, cc.group
    hq, hkv, dh, qpk = mc.n_q_heads, mc.n_kv_heads, mc.d_head, mc.q_per_kv
    n_params = len(param_spec(mc))
    manifest = decode_input_manifest(mc, cc, var)
    names = [n for n, _, _ in manifest]

    def decode(*args):
        params = unflatten_params(list(args[:n_params]), mc)
        ins = dict(zip(names[n_params:], args[n_params:]))
        token, pos, qlen, rlen, rot = (
            ins["token"], ins["pos"], ins["qlen"], ins["rlen"], ins["rot"]
        )
        h = params["embed"][token]                            # [B, d]
        cos, sin = rope_tables(pos, dh, mc.rope_theta)        # [B, dh/2]
        scale = 1.0 / jnp.sqrt(dh)
        qmask = (jnp.arange(c)[None] < qlen[:, None])         # [B, C]
        rmask = (jnp.arange(r)[None] < rlen[:, None])         # [B, R]
        knews, vnews, qabss = [], [], []

        for l, (n16, n4, n2, vb) in enumerate(var.layers):
            x = rmsnorm(h, params[f"l{l}.ln1"], mc.rmsnorm_eps)
            q = (x @ params[f"l{l}.wq"]).reshape(b, hq, dh)
            k = (x @ params[f"l{l}.wk"]).reshape(b, hkv, dh)
            v = (x @ params[f"l{l}.wv"]).reshape(b, hkv, dh)
            q = apply_rope(q, cos[:, None], sin[:, None])
            k = apply_rope(k, cos[:, None], sin[:, None])
            knews.append(k)
            vnews.append(v)
            qg = q.reshape(b, hkv, qpk, dh)
            qabss.append(jnp.mean(jnp.abs(qg), axis=2))       # [B, Hkv, dh]
            qrot = qg @ rot                                   # quantized-space q

            # -- scores vs the packed quantized window (L1 kernels) --------
            def gather_q(idx):                                 # [B,Hkv,n] -> [B,Hkv,qpk,n]
                return jnp.take_along_axis(
                    qrot, idx[:, :, None, :].repeat(qpk, axis=2), axis=-1
                )

            empty_q = jnp.zeros((b, hkv, qpk, 0), jnp.float32)
            empty_p = jnp.zeros((b, hkv, c, 0), jnp.uint8)
            empty_s = jnp.zeros((b, hkv, c // g, 0), jnp.float32)
            q16 = gather_q(ins[f"l{l}.idx16"]) if n16 else empty_q
            q4 = gather_q(ins[f"l{l}.idx4"]) if n4 else empty_q
            q2 = gather_q(ins[f"l{l}.idx2"]) if n2 else empty_q
            k16 = ins.get(f"l{l}.k16", jnp.zeros((b, hkv, c, 0), jnp.float32))
            k4p = ins.get(f"l{l}.k4p", empty_p)
            k4s = ins.get(f"l{l}.k4s", empty_s)
            k4z = ins.get(f"l{l}.k4z", empty_s)
            k2p = ins.get(f"l{l}.k2p", empty_p)
            k2s = ins.get(f"l{l}.k2s", empty_s)
            k2z = ins.get(f"l{l}.k2z", empty_s)

            kernel = functools.partial(mixed_qk_scores, group=g)
            sq = jax.vmap(jax.vmap(kernel))(
                q16, q4, q2, k16, k4p, k4s, k4z, k2p, k2s, k2z
            )                                                  # [B,Hkv,qpk,C]

            # -- scores vs residual + self (full precision, unrotated) -----
            sr = jnp.einsum("bhgd,bhrd->bhgr", qg, ins[f"l{l}.kres"])
            ss = jnp.einsum("bhgd,bhd->bhg", qg, k)[..., None]
            s_all = jnp.concatenate([sq, sr, ss], axis=-1) * scale
            mask = jnp.concatenate(
                [qmask, rmask, jnp.ones((b, 1), bool)], axis=-1
            )[:, None, None, :]
            s_all = jnp.where(mask, s_all, -1e30)
            p = jax.nn.softmax(s_all, axis=-1)
            pq, pr, pself = p[..., :c], p[..., c:c + r], p[..., c + r:]

            # -- weighted values -------------------------------------------
            if vb == 16:
                oq = jnp.einsum("bhgc,bhcd->bhgd", pq, ins[f"l{l}.vfull"])
            else:
                avk = functools.partial(quant_av, group=g, bits=vb)
                oq = jax.vmap(jax.vmap(avk))(
                    pq, ins[f"l{l}.vp"], ins[f"l{l}.vs"], ins[f"l{l}.vz"]
                )
            orr = jnp.einsum("bhgr,bhrd->bhgd", pr, ins[f"l{l}.vres"])
            os = pself * v[:, :, None, :]
            o = (oq + orr + os).reshape(b, hq * dh)
            h = h + o @ params[f"l{l}.wo"]
            x = rmsnorm(h, params[f"l{l}.ln2"], mc.rmsnorm_eps)
            h = h + mlp(x, params[f"l{l}.w1"], params[f"l{l}.w2"])

        h = rmsnorm(h, params["ln_f"], mc.rmsnorm_eps)
        logits = h @ params["embed"].T
        return (logits, jnp.stack(knews), jnp.stack(vnews), jnp.stack(qabss))

    return decode
