"""Shared model / quantization configuration for the MixKVQ reproduction.

This is the single source of truth for shapes. `aot.py` serializes it to
``artifacts/meta.json`` and the Rust side (``rust/src/model/config.rs``)
deserializes it, so the two layers can never drift.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Tuple

# ---------------------------------------------------------------------------
# Vocabulary layout (mirrored in rust/src/model/tokenizer.rs)
# ---------------------------------------------------------------------------
VOCAB = 128
PAD, BOS, EOS, SEP, EQ, ARROW, QMARK, KEY, VAL, COPY = range(10)
OP_ADD, OP_SUB, OP_MUL = 10, 11, 12
NUM_BASE = 16      # token NUM_BASE + v encodes the number v
NUM_COUNT = 32     # values 0..31 (small enough for a ~600k-param model
                   # to master modular arithmetic within the train budget)
FILLER_BASE = 80   # filler "letters" 80..127
FILLER_COUNT = 48


def num_tok(v: int) -> int:
    assert 0 <= v < NUM_COUNT
    return NUM_BASE + v


@dataclass(frozen=True)
class ModelConfig:
    """MiniReasoner: a GQA + RoPE decoder-only transformer."""

    vocab: int = VOCAB
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 256
    rope_theta: float = 10000.0
    max_position: int = 704
    rmsnorm_eps: float = 1e-5

    @property
    def q_per_kv(self) -> int:
        return self.n_q_heads // self.n_kv_heads


@dataclass(frozen=True)
class CacheConfig:
    """Quantized-cache geometry shared by python (lowering) and rust (runtime)."""

    capacity: int = 512      # C: quantized token slots
    residual: int = 128      # R_max: full-precision residual buffer slots
    group: int = 32          # G: quantization group size
    decode_batch: int = 8    # B: static decode batch (padded with idle slots)
    prefill_buckets: Tuple[int, ...] = (128, 512)

    @property
    def key_groups(self) -> int:
        return self.capacity // self.group


@dataclass
class QuantVariant:
    """A compile-time quantization layout.

    Per layer: (n16, n4, n2) key-channel tier counts summing to d_head, and
    the value bit-width v_bits in {2, 4, 16}. The paper's thresholds
    (tau_BF16, tau_UINT4) select *which* channels land in each tier at
    runtime; the *counts* are fixed per variant so the HLO stays
    static-shaped (see DESIGN.md §Hardware-Adaptation).
    """

    name: str = "bf16"
    # one (n16, n4, n2, v_bits) tuple per layer
    layers: List[Tuple[int, int, int, int]] = field(default_factory=list)

    def key_bits(self, d_head: int) -> float:
        tot = sum(16 * a + 4 * b + 2 * c for a, b, c, _ in self.layers)
        return tot / (d_head * len(self.layers))

    def avg_bits(self, d_head: int) -> float:
        kb = self.key_bits(d_head)
        vb = sum(v for _, _, _, v in self.layers) / len(self.layers)
        return (kb + vb) / 2.0


def uniform_variant(name: str, n_layers: int, n16: int, n4: int, n2: int, v_bits: int) -> QuantVariant:
    return QuantVariant(name=name, layers=[(n16, n4, n2, v_bits)] * n_layers)


def default_variants(mc: ModelConfig) -> List[QuantVariant]:
    d, L = mc.d_head, mc.n_layers
    assert d == 32, "tier presets assume d_head=32"
    vs = [
        uniform_variant("bf16", L, d, 0, 0, 16),
        uniform_variant("kv4", L, 0, d, 0, 4),      # KIVI/KVQuant/RotateKV @4
        uniform_variant("kv2", L, 0, 0, d, 2),      # KIVI/KVQuant/RotateKV @2
        uniform_variant("k4v2", L, 0, d, 0, 2),     # Table 2 asymmetry probe
        uniform_variant("k2v4", L, 0, 0, d, 4),     # Table 2 asymmetry probe
        # MixKVQ tiered layouts (key bits 2.25 / 3.0 / 3.25)
        uniform_variant("mix225", L, 0, 4, 28, 2),
        uniform_variant("mix30", L, 2, 2, 28, 2),
        uniform_variant("mix325", L, 2, 6, 24, 2),
    ]
    # KVTuner-style static layer-wise mix: calibration marks layers 0,3 as
    # sensitive (KV4) and 1,2 as non-critical (KV2) — App. B failure mode.
    vs.append(
        QuantVariant(
            name="kvtuner",
            layers=[(0, d, 0, 4), (0, 0, d, 2), (0, 0, d, 2), (0, d, 0, 4)],
        )
    )
    return vs


def validate_variant(v: QuantVariant, mc: ModelConfig, cc: CacheConfig) -> None:
    assert len(v.layers) == mc.n_layers, v.name
    for (n16, n4, n2, vb) in v.layers:
        assert n16 + n4 + n2 == mc.d_head, v.name
        assert n4 % 2 == 0, f"{v.name}: n4 must pack into bytes"
        assert n2 % 4 == 0, f"{v.name}: n2 must pack into bytes"
        assert vb in (2, 4, 16), v.name
    assert cc.capacity % cc.group == 0
    assert cc.residual % cc.group == 0


def meta_dict(mc: ModelConfig, cc: CacheConfig, variants: List[QuantVariant]) -> dict:
    return {
        "model": asdict(mc),
        "cache": asdict(cc),
        "variants": [
            {
                "name": v.name,
                "layers": [list(t) for t in v.layers],
                "key_bits": v.key_bits(mc.d_head),
                "avg_bits": v.avg_bits(mc.d_head),
            }
            for v in variants
        ],
    }
