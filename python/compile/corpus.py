"""Synthetic training corpus for MiniReasoner.

Four task families, chosen to mirror what the paper's benchmarks measure
(see DESIGN.md §2 substitution table):

* ``chain``   — chained modular arithmetic: the AIME/MATH stand-in. The
                answer of step *i* feeds step *i+1*, so a single corrupted
                logit invalidates the remainder of the chain (Table 1's
                error-accumulation phenomenon).
* ``passkey`` — needle-in-a-haystack retrieval: the LongBench stand-in.
* ``kvlookup``— associative recall over many KEY/VAL pairs.
* ``copy``    — verbatim copy, the purest attention-fidelity probe.

The Rust harness re-implements the same generators (harness/workloads.rs);
distributional identity is by construction, not by shared RNG state.
"""

import numpy as np

from .config import (
    ARROW, BOS, COPY, EOS, EQ, FILLER_BASE, FILLER_COUNT, KEY, NUM_COUNT,
    OP_ADD, OP_MUL, OP_SUB, QMARK, SEP, VAL, num_tok,
)

# MUL mod N is a 3-way table a ~600k model cannot master in the CPU train
# budget; ADD/SUB keep the chain task learnable while preserving its
# all-or-nothing error-accumulation structure.
OPS = [OP_ADD, OP_SUB]


def apply_op(op: int, a: int, b: int) -> int:
    if op == OP_ADD:
        return (a + b) % NUM_COUNT
    if op == OP_SUB:
        return (a - b) % NUM_COUNT
    if op == OP_MUL:
        return (a * b) % NUM_COUNT
    raise ValueError(op)


CHAIN_OPERAND_MAX = 5  # operands 1..4: a small op table a ~600k model can
                       # master, while the chained structure still makes a
                       # single corrupted step invalidate the rest (Table 1).


def gen_chain(rng: np.random.Generator, steps: int):
    """Returns (tokens, answer_positions). Each step: prev OP nb EQ res SEP."""
    toks = [BOS]
    answers = []  # (position_of_result_token, result_token)
    prev = int(rng.integers(NUM_COUNT))
    toks.append(num_tok(prev))
    for _ in range(steps):
        op = OPS[int(rng.integers(len(OPS)))]
        b = int(rng.integers(1, CHAIN_OPERAND_MAX))
        res = apply_op(op, prev, b)
        toks += [op, num_tok(b), EQ]
        answers.append((len(toks), num_tok(res)))
        toks += [num_tok(res), SEP]
        prev = res
    toks.append(EOS)
    return toks, answers


def gen_passkey(rng: np.random.Generator, context_len: int, key_len: int = 2, val_len: int = 2):
    key = [num_tok(int(rng.integers(NUM_COUNT))) for _ in range(key_len)]
    val = [num_tok(int(rng.integers(NUM_COUNT))) for _ in range(val_len)]
    needle = [KEY] + key + [VAL] + val
    query = [QMARK] + key + [ARROW]
    n_fill = max(0, context_len - len(needle) - len(query) - val_len - 2)
    pos = int(rng.integers(n_fill + 1))
    filler = rng.integers(FILLER_BASE, FILLER_BASE + FILLER_COUNT, size=n_fill).tolist()
    toks = [BOS] + filler[:pos] + needle + filler[pos:] + query
    answers = [(len(toks) + i, val[i]) for i in range(val_len)]
    toks += val + [EOS]
    return toks, answers


def gen_kvlookup(rng: np.random.Generator, n_pairs: int):
    keys = rng.choice(NUM_COUNT, size=n_pairs, replace=False)
    vals = rng.integers(NUM_COUNT, size=n_pairs)
    toks = [BOS]
    for k, v in zip(keys, vals):
        toks += [KEY, num_tok(int(k)), VAL, num_tok(int(v)), SEP]
    i = int(rng.integers(n_pairs))
    toks += [QMARK, num_tok(int(keys[i])), ARROW]
    answers = [(len(toks), num_tok(int(vals[i])))]
    toks += [num_tok(int(vals[i])), EOS]
    return toks, answers


def gen_copy(rng: np.random.Generator, n: int):
    seq = [num_tok(int(t)) for t in rng.integers(NUM_COUNT, size=n)]
    toks = [BOS, COPY] + seq + [ARROW]
    answers = [(len(toks) + i, seq[i]) for i in range(n)]
    toks += seq + [EOS]
    return toks, answers


def sample_example(rng: np.random.Generator, max_len: int):
    kind = int(rng.integers(4))
    if kind == 0:
        toks, ans = gen_chain(rng, steps=int(rng.integers(2, 9)))
    elif kind == 1:
        toks, ans = gen_passkey(rng, context_len=int(rng.integers(24, max(25, max_len - 10))))
    elif kind == 2:
        toks, ans = gen_kvlookup(rng, n_pairs=int(rng.integers(2, 13)))
    else:
        toks, ans = gen_copy(rng, n=int(rng.integers(2, 13)))
    return toks[:max_len], [(p, t) for p, t in ans if p < max_len]


ANSWER_WEIGHT = 5.0  # focus capacity on the tokens the harness scores


def make_batch(rng: np.random.Generator, batch: int, seq_len: int):
    """Padded (tokens, loss_weights) arrays for next-token training.

    Answer positions get ANSWER_WEIGHT; other (partly unlearnable filler)
    positions weight 1. This concentrates the tiny model's capacity on the
    retrieval/arithmetic behaviour the quantization experiments measure.
    """
    x = np.zeros((batch, seq_len), dtype=np.int32)
    mask = np.zeros((batch, seq_len), dtype=np.float32)
    for b in range(batch):
        toks, answers = sample_example(rng, seq_len)
        n = len(toks)
        x[b, :n] = toks
        mask[b, : max(0, n - 1)] = 1.0  # predict every non-pad next token
        for pos, _ in answers:
            if 0 < pos < seq_len:
                mask[b, pos - 1] = ANSWER_WEIGHT
    return x, mask
