"""Train MiniReasoner on the synthetic corpus (build-time only).

Hand-rolled Adam (optax is not in the image). The loss curve is written to
artifacts/train_log.json — this is the training record referenced by
EXPERIMENTS.md. Run directly for a standalone training:

    cd python && python -m compile.train --steps 800 --out ../artifacts
"""

import argparse
import functools
import json
import os
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .config import ModelConfig
from .model import flatten_params, forward_train, init_params, param_spec


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 800
    batch: int = 16
    seq_len: int = 96
    lr: float = 3e-3
    warmup: int = 50
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    seed: int = 0
    log_every: int = 25


def loss_fn(params, tokens, mask, mc: ModelConfig):
    logits, _ = forward_train(params, tokens, mc)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, : tgt.shape[1]]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


@functools.partial(jax.jit, static_argnames=("mc", "tc"))
def train_step(params, m_state, v_state, step, tokens, mask, mc: ModelConfig, tc: TrainConfig):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, mask, mc)
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    decay = 0.5 * (1 + jnp.cos(jnp.pi * step / tc.steps))
    lr = tc.lr * warm * (0.1 + 0.9 * decay)

    m2 = jax.tree.map(lambda m, g: tc.beta1 * m + (1 - tc.beta1) * g, m_state, grads)
    v2 = jax.tree.map(lambda v, g: tc.beta2 * v + (1 - tc.beta2) * g * g, v_state, grads)
    bc1 = 1 - tc.beta1 ** (step + 1)
    bc2 = 1 - tc.beta2 ** (step + 1)
    params2 = jax.tree.map(
        lambda p, m, v: p
        - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + tc.eps) + tc.weight_decay * p),
        params,
        m2,
        v2,
    )
    return params2, m2, v2, loss


def greedy_eval(params, mc: ModelConfig, seed: int = 1234, n: int = 32):
    """Teacher-forced answer-token accuracy per task family (full precision)."""
    rng = np.random.default_rng(seed)
    gens = {
        "chain": lambda: corpus.gen_chain(rng, steps=6),
        "passkey": lambda: corpus.gen_passkey(rng, context_len=64),
        "kvlookup": lambda: corpus.gen_kvlookup(rng, n_pairs=8),
        "copy": lambda: corpus.gen_copy(rng, n=8),
    }
    fwd = jax.jit(lambda p, t: forward_train(p, t, mc)[0])
    acc = {}
    for name, gen in gens.items():
        hit = tot = 0
        for _ in range(n):
            toks, answers = gen()
            x = jnp.asarray(np.array(toks, np.int32)[None])
            logits = np.asarray(fwd(params, x))[0]
            for pos, want in answers:
                tot += 1
                hit += int(np.argmax(logits[pos - 1]) == want)
        acc[name] = hit / max(tot, 1)
    return acc


def save_weights(params, mc: ModelConfig, path: str):
    flat = flatten_params(params, mc)
    buf = b"".join(np.asarray(a, np.float32).tobytes() for a in flat)
    with open(path, "wb") as f:
        f.write(buf)
    return len(buf)


def train(mc: ModelConfig, tc: TrainConfig, out_dir: str, verbose: bool = True):
    rng = np.random.default_rng(tc.seed)
    params = init_params(mc, seed=tc.seed)
    zeros = jax.tree.map(jnp.zeros_like, params)
    m_state, v_state = zeros, jax.tree.map(jnp.zeros_like, params)
    log = []
    t0 = time.time()
    for step in range(tc.steps):
        x, mask = corpus.make_batch(rng, tc.batch, tc.seq_len)
        params, m_state, v_state, loss = train_step(
            params, m_state, v_state, step, jnp.asarray(x), jnp.asarray(mask), mc, tc
        )
        if step % tc.log_every == 0 or step == tc.steps - 1:
            l = float(loss)
            log.append({"step": step, "loss": l, "elapsed_s": round(time.time() - t0, 1)})
            if verbose:
                print(f"step {step:5d}  loss {l:.4f}  ({time.time()-t0:.0f}s)", flush=True)
    acc = greedy_eval(params, mc)
    if verbose:
        print("final task accuracy (BF16, teacher-forced):", acc)
    os.makedirs(out_dir, exist_ok=True)
    nbytes = save_weights(params, mc, os.path.join(out_dir, "weights.bin"))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(
            {
                "config": tc.__dict__,
                "n_params": int(sum(int(np.prod(s)) for _, s in param_spec(mc))),
                "weights_bytes": nbytes,
                "loss_curve": log,
                "final_accuracy": acc,
            },
            f,
            indent=2,
        )
    return params, acc


def long_context_batch(rng: np.random.Generator, batch: int, seq_len: int):
    """Stage-2 curriculum: long passkeys / deep chains / many-pair lookups,
    so RoPE sees positions up to seq_len (evals go to ~460)."""
    x = np.zeros((batch, seq_len), dtype=np.int32)
    mask = np.zeros((batch, seq_len), dtype=np.float32)
    for b in range(batch):
        r = rng.random()
        if r < 0.45:
            toks, ans = corpus.gen_passkey(rng, context_len=int(rng.integers(48, seq_len - 8)))
        elif r < 0.75:
            toks, ans = corpus.gen_kvlookup(rng, n_pairs=int(rng.integers(4, 25)))
        elif r < 0.92:
            toks, ans = corpus.gen_chain(rng, steps=int(rng.integers(6, min(48, (seq_len - 4) // 5))))
        else:
            toks, ans = corpus.gen_copy(rng, n=int(rng.integers(4, 17)))
        toks = toks[:seq_len]
        n = len(toks)
        x[b, :n] = toks
        mask[b, : max(0, n - 1)] = 1.0
        for pos, _ in ans:
            if 0 < pos < seq_len:
                mask[b, pos - 1] = corpus.ANSWER_WEIGHT
    return x, mask


def finetune_long(params, mc: ModelConfig, out_dir: str, steps: int = 1600,
                  seq_len: int = 320, batch: int = 4, lr: float = 1e-3, verbose=True):
    """Stage 2: extend positional coverage + sharpen retrieval."""
    tc = TrainConfig(steps=steps, batch=batch, seq_len=seq_len, lr=lr, warmup=50)
    rng = np.random.default_rng(1)
    m_state = jax.tree.map(jnp.zeros_like, params)
    v_state = jax.tree.map(jnp.zeros_like, params)
    t0 = time.time()
    log = []
    for step in range(steps):
        x, mask = long_context_batch(rng, batch, seq_len)
        params, m_state, v_state, loss = train_step(
            params, m_state, v_state, step, jnp.asarray(x), jnp.asarray(mask), mc, tc
        )
        if step % 200 == 0 or step == steps - 1:
            l = float(loss)
            log.append({"step": step, "loss": l})
            if verbose:
                print(f"[stage2] step {step:5d}  loss {l:.4f}  ({time.time()-t0:.0f}s)", flush=True)
    acc = greedy_eval(params, mc)
    if verbose:
        print("[stage2] final task accuracy:", acc)
    save_weights(params, mc, os.path.join(out_dir, "weights.bin"))
    with open(os.path.join(out_dir, "finetune_log.json"), "w") as f:
        json.dump({"steps": steps, "seq_len": seq_len, "loss_curve": log,
                   "final_accuracy": acc}, f, indent=2)
    return params, acc


def load_params(path: str, mc: ModelConfig):
    raw = np.fromfile(path, dtype=np.float32)
    params = {}
    off = 0
    for name, shape in param_spec(mc):
        n = int(np.prod(shape))
        params[name] = jnp.asarray(raw[off:off + n].reshape(shape))
        off += n
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=800)
    ap.add_argument("--stage2-steps", type=int, default=1600)
    ap.add_argument("--stage2-only", action="store_true")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    mc = ModelConfig()
    if args.stage2_only:
        params = load_params(os.path.join(args.out, "weights.bin"), mc)
    else:
        tc = TrainConfig(steps=args.steps)
        params, _ = train(mc, tc, args.out)
    if args.stage2_steps > 0:
        finetune_long(params, mc, args.out, steps=args.stage2_steps)


if __name__ == "__main__":
    main()
