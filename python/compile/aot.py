"""AOT entry point: train (if needed) + lower every HLO artifact.

Produces in artifacts/:
  weights.bin                  — flat f32 LE in param_spec order
  meta.json                    — model/cache/variant/tokenizer ABI
  train_log.json               — loss curve + BF16 task accuracy
  prefill_t<T>.hlo.txt         — prompt prefill per bucket
  prefill_t<T>.inputs.json     — positional input manifest
  decode_<variant>.hlo.txt     — batched quantized decode step per variant
  decode_<variant>.inputs.json

HLO *text* is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .config import (
    CacheConfig, ModelConfig, default_variants, meta_dict, validate_variant,
)
from .model import (
    decode_input_manifest, make_decode, make_prefill, prefill_input_manifest,
)
from .train import TrainConfig, train

DTYPES = {"f32": np.float32, "i32": np.int32, "u8": np.uint8}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_structs(manifest):
    return [
        jax.ShapeDtypeStruct(tuple(shape), DTYPES[dt]) for _, shape, dt in manifest
    ]


def write_artifact(fn, manifest, name: str, out_dir: str, verbose=True):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*shape_structs(manifest))
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(out_dir, f"{name}.inputs.json"), "w") as f:
        json.dump(
            [{"name": n, "shape": list(s), "dtype": dt} for n, s, dt in manifest], f
        )
    if verbose:
        print(
            f"  {name}: {len(text) / 1e6:.2f} MB HLO, {len(manifest)} inputs, "
            f"{time.time() - t0:.1f}s",
            flush=True,
        )


def build(out_dir: str, train_steps: int = 12000, force_train: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    mc, cc = ModelConfig(), CacheConfig()
    variants = default_variants(mc)
    for v in variants:
        validate_variant(v, mc, cc)

    wpath = os.path.join(out_dir, "weights.bin")
    if force_train or not os.path.exists(wpath):
        print(f"training MiniReasoner (stage1 {train_steps} steps + stage2 long-context)...", flush=True)
        params, _ = train(mc, TrainConfig(steps=train_steps), out_dir)
        from .train import finetune_long
        finetune_long(params, mc, out_dir)
    else:
        print("weights.bin exists, skipping training", flush=True)

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta_dict(mc, cc, variants), f, indent=2)

    print("lowering prefill buckets...", flush=True)
    for t in cc.prefill_buckets:
        write_artifact(
            make_prefill(mc, t), prefill_input_manifest(mc, t), f"prefill_t{t}", out_dir
        )

    print("lowering decode variants...", flush=True)
    for v in variants:
        write_artifact(
            make_decode(mc, cc, v),
            decode_input_manifest(mc, cc, v),
            f"decode_{v.name}",
            out_dir,
        )
    print("artifacts complete", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=12000)
    ap.add_argument("--force-train", action="store_true")
    args = ap.parse_args()
    build(args.out, args.train_steps, args.force_train)


if __name__ == "__main__":
    main()


# Kept for Makefile compatibility / quick smoke use: a trivial single-op
# artifact proving the tool-chain end-to-end (not used by the runtime).
def smoke(out_path: str):
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), np.float32)
    )
    open(out_path, "w").write(to_hlo_text(lowered))
