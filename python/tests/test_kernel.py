"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps tier splits, cache lengths, and value bit-widths.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant as Q, ref as R
from compile.kernels.quant_attn import mixed_qk_scores, quant_av

G = 32


def make_tiers(rng, c, d, n16, n4, n2):
    k = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    k16 = k[:, :n16]
    if n4:
        k4p, k4s, k4z = Q.quantize_key_channelwise(k[:, n16:n16 + n4], G, 4)
    else:
        k4p = jnp.zeros((c, 0), jnp.uint8)
        k4s = k4z = jnp.zeros((c // G, 0), jnp.float32)
    if n2:
        k2p, k2s, k2z = Q.quantize_key_channelwise(k[:, n16 + n4:], G, 2)
    else:
        k2p = jnp.zeros((c, 0), jnp.uint8)
        k2s = k2z = jnp.zeros((c // G, 0), jnp.float32)
    q16, q4, q2 = q[:, :n16], q[:, n16:n16 + n4], q[:, n16 + n4:]
    return (q16, q4, q2, k16, k4p, k4s, k4z, k2p, k2s, k2z)


TIER_SPLITS = st.sampled_from(
    [(32, 0, 0), (0, 32, 0), (0, 0, 32), (2, 6, 24), (0, 4, 28), (2, 2, 28),
     (1, 2, 4), (8, 8, 16), (4, 0, 28), (0, 8, 24)]
)


@given(split=TIER_SPLITS, c=st.sampled_from([128, 256, 512]),
       seed=st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_mixed_qk_scores_matches_ref(split, c, seed):
    n16, n4, n2 = split
    d = n16 + n4 + n2
    rng = np.random.default_rng(seed)
    args = make_tiers(rng, c, d, n16, n4, n2)
    ref = R.ref_mixed_scores(*args, group=G)
    out = mixed_qk_scores(*args, group=G)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


@given(bits=st.sampled_from([2, 4]), c=st.sampled_from([128, 384]),
       hq=st.sampled_from([1, 4]), seed=st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_quant_av_matches_ref(bits, c, hq, seed):
    d = 32
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    vp, vs, vz = Q.quantize_value_tokenwise(v, G, bits)
    p = jnp.asarray(rng.random(size=(hq, c)).astype(np.float32))
    ref = R.ref_quant_av(p, vp, vs, vz, G, bits)
    out = quant_av(p, vp, vs, vz, group=G, bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-4)


def test_scores_bf16_tier_is_exact():
    """With everything in the f16 tier the kernel is a plain matmul."""
    rng = np.random.default_rng(0)
    args = make_tiers(rng, 256, 32, 32, 0, 0)
    q16, k16 = args[0], args[3]
    out = mixed_qk_scores(*args, group=G)
    np.testing.assert_allclose(np.asarray(out), np.asarray(q16 @ k16.T), rtol=1e-6)


def test_quantized_scores_close_to_exact_at_4bit():
    """4-bit cache should track exact scores closely (sanity on magnitudes)."""
    rng = np.random.default_rng(1)
    c, d = 256, 32
    k = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    args = make_tiers(rng, c, d, 0, 32, 0)
    # same k used inside make_tiers? no — rebuild explicitly
    k4p, k4s, k4z = Q.quantize_key_channelwise(k, G, 4)
    out = mixed_qk_scores(
        jnp.zeros((4, 0)), q, jnp.zeros((4, 0)),
        jnp.zeros((c, 0)), k4p, k4s, k4z,
        jnp.zeros((c, 0), jnp.uint8), jnp.zeros((c // G, 0)), jnp.zeros((c // G, 0)),
        group=G,
    )
    exact = q @ k.T
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.12, rel


def test_2bit_worse_than_4bit():
    rng = np.random.default_rng(2)
    c, d = 256, 32
    k = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    exact = q @ k.T

    def err(bits):
        p, s, z = Q.quantize_key_channelwise(k, G, bits)
        kd = Q.dequantize_key_channelwise(p, s, z, G, bits)
        return float(jnp.linalg.norm(q @ kd.T - exact))

    assert err(2) > 2 * err(4)
