"""Quantization primitive tests: packing roundtrips + the Appendix-A bound."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant as Q


def rand(shape, seed=0, lo=-3.0, hi=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

@given(st.integers(1, 64), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_u4_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 16, size=(5, 2 * n)).astype(np.uint8))
    assert jnp.array_equal(Q.unpack_u4(Q.pack_u4(q)), q)


@given(st.integers(1, 32), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_u2_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(0, 4, size=(3, 4 * n)).astype(np.uint8))
    assert jnp.array_equal(Q.unpack_u2(Q.pack_u2(q)), q)


def test_pack_u4_nibble_order():
    # byte j = channel 2j in the low nibble — the rust ABI (packing.rs)
    q = jnp.asarray(np.array([[0x3, 0xA]], np.uint8))
    assert int(Q.pack_u4(q)[0, 0]) == 0x3 | (0xA << 4)


def test_pack_u2_crumb_order():
    q = jnp.asarray(np.array([[1, 2, 3, 0]], np.uint8))
    assert int(Q.pack_u2(q)[0, 0]) == 1 | (2 << 2) | (3 << 4)


# ---------------------------------------------------------------------------
# Error bound |x - x~| <= s/2 (Appendix A)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4])
@given(seed=st.integers(0, 2**32 - 1), scale=st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_key_quant_error_bound(bits, seed, scale):
    rng = np.random.default_rng(seed)
    k = jnp.asarray((rng.normal(size=(64, 8)) * scale).astype(np.float32))
    p, s, z = Q.quantize_key_channelwise(k, group=32, bits=bits)
    kd = Q.dequantize_key_channelwise(p, s, z, group=32, bits=bits)
    bound = jnp.repeat(s, 32, axis=0) / 2
    assert bool(jnp.all(jnp.abs(kd - k) <= bound * (1 + 1e-5) + 1e-6))


@pytest.mark.parametrize("bits", [2, 4])
def test_value_quant_error_bound(bits):
    v = rand((96, 32), seed=3, lo=-10, hi=10)
    p, s, z = Q.quantize_value_tokenwise(v, group=32, bits=bits)
    vd = Q.dequantize_value_tokenwise(p, s, z, group=32, bits=bits)
    bound = jnp.repeat(s, 32, axis=-1).reshape(v.shape) / 2
    assert bool(jnp.all(jnp.abs(vd - v) <= bound * (1 + 1e-5) + 1e-6))


def test_outlier_inflates_scale():
    """A single outlier inflates s and degrades *other* elements (Sec. 3.2)."""
    k = np.zeros((32, 4), np.float32)  # 4 channels (u2 packs 4 per byte)
    for ch in range(4):
        k[:, ch] = np.linspace(-1, 1, 32)
    k[7, 1] = 100.0  # outlier channel
    p, s, z = Q.quantize_key_channelwise(jnp.asarray(k), group=32, bits=2)
    kd = np.asarray(Q.dequantize_key_channelwise(p, s, z, group=32, bits=2))
    err_clean = np.abs(kd[:, 0] - k[:, 0]).mean()
    mask = np.arange(32) != 7
    err_outlier_chan = np.abs(kd[mask, 1] - k[mask, 1]).mean()
    assert err_outlier_chan > 5 * err_clean


def test_constant_channel_zero_error():
    k = jnp.ones((32, 4)) * 2.5
    p, s, z = Q.quantize_key_channelwise(k, group=32, bits=2)
    kd = Q.dequantize_key_channelwise(p, s, z, group=32, bits=2)
    assert float(jnp.max(jnp.abs(kd - k))) < 1e-5


@pytest.mark.parametrize("bits,levels", [(2, 4), (4, 16)])
def test_codes_in_range(bits, levels):
    k = rand((64, 8), seed=9)
    p, _, _ = Q.quantize_key_channelwise(k, group=32, bits=bits)
    codes = np.asarray(Q.unpack(p, bits))
    assert codes.max() < levels
