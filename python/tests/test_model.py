"""L2 model tests: decode-over-cache consistency with the training forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import CacheConfig, ModelConfig, default_variants
from compile.kernels import quant as Q

MC = ModelConfig()
CC = CacheConfig()
VARIANTS = {v.name: v for v in default_variants(MC)}


@pytest.fixture(scope="module")
def params():
    return M.init_params(MC, seed=7)


def residual_only_inputs(params, k, v, t, token, var):
    """All context in the residual buffer; quantized window empty."""
    b, c, r, hkv, dh = CC.decode_batch, CC.capacity, CC.residual, MC.n_kv_heads, MC.d_head
    ins = {
        "token": jnp.zeros((b,), jnp.int32).at[0].set(token),
        "pos": jnp.zeros((b,), jnp.int32).at[0].set(t),
        "qlen": jnp.zeros((b,), jnp.int32),
        "rlen": jnp.zeros((b,), jnp.int32).at[0].set(t),
        "rot": jnp.eye(dh),
    }
    for l in range(MC.n_layers):
        n16, n4, n2, vb = var.layers[l]
        if n16:
            ins[f"l{l}.idx16"] = jnp.tile(jnp.arange(n16, dtype=jnp.int32), (b, hkv, 1))
            ins[f"l{l}.k16"] = jnp.zeros((b, hkv, c, n16))
        if n4:
            ins[f"l{l}.idx4"] = jnp.tile(jnp.arange(n16, n16 + n4, dtype=jnp.int32), (b, hkv, 1))
            ins[f"l{l}.k4p"] = jnp.zeros((b, hkv, c, n4 // 2), jnp.uint8)
            ins[f"l{l}.k4s"] = jnp.full((b, hkv, c // CC.group, n4), 1e-8)
            ins[f"l{l}.k4z"] = jnp.zeros((b, hkv, c // CC.group, n4))
        if n2:
            ins[f"l{l}.idx2"] = jnp.tile(jnp.arange(n16 + n4, dh, dtype=jnp.int32), (b, hkv, 1))
            ins[f"l{l}.k2p"] = jnp.zeros((b, hkv, c, n2 // 4), jnp.uint8)
            ins[f"l{l}.k2s"] = jnp.full((b, hkv, c // CC.group, n2), 1e-8)
            ins[f"l{l}.k2z"] = jnp.zeros((b, hkv, c // CC.group, n2))
        if vb == 16:
            ins[f"l{l}.vfull"] = jnp.zeros((b, hkv, c, dh))
        else:
            ins[f"l{l}.vp"] = jnp.zeros((b, hkv, c, dh * vb // 8), jnp.uint8)
            ins[f"l{l}.vs"] = jnp.full((b, hkv, c, dh // CC.group), 1e-8)
            ins[f"l{l}.vz"] = jnp.zeros((b, hkv, c, dh // CC.group))
        kres = jnp.zeros((b, hkv, r, dh)).at[0, :, :t].set(k[l, 0, :t].transpose(1, 0, 2))
        vres = jnp.zeros((b, hkv, r, dh)).at[0, :, :t].set(v[l, 0, :t].transpose(1, 0, 2))
        ins[f"l{l}.kres"] = kres
        ins[f"l{l}.vres"] = vres
    return ins


def run_decode(params, var, ins):
    manifest = M.decode_input_manifest(MC, CC, var)
    names = [n for n, _, _ in manifest]
    flat = M.flatten_params(params, MC)
    args = flat + [ins[n] for n in names[len(flat):]]
    return jax.jit(M.make_decode(MC, CC, var))(*args)


@pytest.mark.parametrize("vname", ["bf16", "kv4", "mix30"])
def test_decode_residual_only_matches_forward(params, vname):
    """With the whole context in the residual buffer, every variant must
    reproduce the full-precision forward exactly (no quantization touches
    the residual path)."""
    rng = np.random.default_rng(0)
    t = 24
    toks = jnp.asarray(rng.integers(1, MC.vocab, size=(1, t + 1)), jnp.int32)
    logits_full, (k, v, _) = M.forward_train(params, toks, MC)
    ins = residual_only_inputs(params, k, v, t, int(toks[0, t]), VARIANTS[vname])
    out = run_decode(params, VARIANTS[vname], ins)
    np.testing.assert_allclose(
        np.asarray(out[0][0]), np.asarray(logits_full[0, t]), rtol=1e-4, atol=1e-4
    )


def test_decode_emits_new_kv_matching_forward(params):
    rng = np.random.default_rng(1)
    t = 12
    toks = jnp.asarray(rng.integers(1, MC.vocab, size=(1, t + 1)), jnp.int32)
    _, (k, v, _) = M.forward_train(params, toks, MC)
    ins = residual_only_inputs(params, k, v, t, int(toks[0, t]), VARIANTS["bf16"])
    _, knew, vnew, _ = run_decode(params, VARIANTS["bf16"], ins)
    # knew [L, B, Hkv, dh] must equal the forward's K at position t
    np.testing.assert_allclose(
        np.asarray(knew[:, 0]), np.asarray(k[:, 0, t]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(vnew[:, 0]), np.asarray(v[:, 0, t]), rtol=1e-4, atol=1e-5
    )


def test_qabs_is_mean_abs_query(params):
    rng = np.random.default_rng(2)
    t = 8
    toks = jnp.asarray(rng.integers(1, MC.vocab, size=(1, t + 1)), jnp.int32)
    _, (k, v, qabs_tr) = M.forward_train(params, toks, MC)
    ins = residual_only_inputs(params, k, v, t, int(toks[0, t]), VARIANTS["bf16"])
    _, _, _, qabs = run_decode(params, VARIANTS["bf16"], ins)
    np.testing.assert_allclose(
        np.asarray(qabs[:, 0]), np.asarray(qabs_tr[:, 0, t]), rtol=1e-4, atol=1e-5
    )


def test_prefill_matches_forward(params):
    rng = np.random.default_rng(3)
    t_bucket, n = 128, 50
    toks = np.zeros(t_bucket, np.int32)
    toks[:n] = rng.integers(1, MC.vocab, size=n)
    prefill = jax.jit(M.make_prefill(MC, t_bucket))
    flat = M.flatten_params(params, MC)
    last, k, v, qabs = prefill(*flat, jnp.asarray(toks), jnp.asarray(n, jnp.int32))
    logits_full, (k2, v2, _) = M.forward_train(params, jnp.asarray(toks[None]), MC)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[0, n - 1]), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(k[:, :, :n]),
        np.asarray(k2[:, 0, :n].transpose(0, 2, 1, 3)),
        rtol=1e-4, atol=1e-5,
    )


def test_rotation_invariance_of_exact_scores(params):
    """Hadamard rotation must not change exact (unquantized) scores:
    (q R)·(k R) = q·k for orthonormal R — the RotateKV soundness condition."""
    dh = MC.d_head
    h = np.array([[1.0]])
    while h.shape[0] < dh:
        h = np.block([[h, h], [h, -h]])
    rot = jnp.asarray((h / np.sqrt(dh)).astype(np.float32))
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(4, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(64, dh)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray((q @ rot) @ (k @ rot).T), np.asarray(q @ k.T), rtol=1e-4, atol=1e-4
    )


def test_variant_bits_accounting():
    v = VARIANTS["mix30"]
    # (2*16 + 2*4 + 28*2) / 32 = 3.0
    assert abs(v.key_bits(MC.d_head) - 3.0) < 1e-9
    assert abs(VARIANTS["mix225"].key_bits(MC.d_head) - 2.25) < 1e-9
    assert abs(VARIANTS["kv2"].avg_bits(MC.d_head) - 2.0) < 1e-9


def test_idle_batch_slots_are_safe(params):
    """Slots with qlen=rlen=0 must produce finite logits (self-attention only)."""
    rng = np.random.default_rng(5)
    t = 4
    toks = jnp.asarray(rng.integers(1, MC.vocab, size=(1, t + 1)), jnp.int32)
    _, (k, v, _) = M.forward_train(params, toks, MC)
    ins = residual_only_inputs(params, k, v, t, int(toks[0, t]), VARIANTS["bf16"])
    out = run_decode(params, VARIANTS["bf16"], ins)
    assert bool(jnp.all(jnp.isfinite(out[0])))  # includes idle slots 1..7
