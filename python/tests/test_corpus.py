"""Corpus generator invariants (hypothesis) — answers must be consistent
with the generated context, mirroring rust/src/harness/workloads.rs tests."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import corpus
from compile.config import (
    ARROW, BOS, EOS, EQ, KEY, NUM_BASE, NUM_COUNT, QMARK, SEP, VAL, VOCAB,
)


@given(seed=st.integers(0, 2**32 - 1), steps=st.integers(1, 20))
@settings(max_examples=50, deadline=None)
def test_chain_answers_are_correct_arithmetic(seed, steps):
    rng = np.random.default_rng(seed)
    toks, answers = corpus.gen_chain(rng, steps)
    assert len(answers) == steps
    assert toks[0] == BOS and toks[-1] == EOS
    for pos, tok in answers:
        assert toks[pos] == tok
        assert toks[pos - 1] == EQ
    # recompute each step from the surface form
    prev = toks[1] - NUM_BASE
    i = 2
    for pos, tok in answers:
        op, b = toks[i], toks[i + 1] - NUM_BASE
        want = (prev + b) % NUM_COUNT if op == corpus.OP_ADD else (prev - b) % NUM_COUNT
        assert tok - NUM_BASE == want
        prev = want
        i = pos + 2  # skip result + SEP


@given(seed=st.integers(0, 2**32 - 1), ctx=st.integers(24, 300))
@settings(max_examples=50, deadline=None)
def test_passkey_answer_matches_needle(seed, ctx):
    rng = np.random.default_rng(seed)
    toks, answers = corpus.gen_passkey(rng, ctx)
    v = toks.index(VAL)
    needle_vals = toks[v + 1:v + 3]
    assert [t for _, t in answers] == needle_vals
    q = toks.index(QMARK)
    k = toks.index(KEY)
    assert toks[q + 1:q + 3] == toks[k + 1:k + 3], "query key == needle key"


@given(seed=st.integers(0, 2**32 - 1), n=st.integers(2, 30))
@settings(max_examples=50, deadline=None)
def test_kvlookup_answer_is_queried_pair(seed, n):
    rng = np.random.default_rng(seed)
    toks, answers = corpus.gen_kvlookup(rng, n)
    q = toks.index(QMARK)
    qkey = toks[q + 1]
    # scan pairs
    pairs = {}
    i = 1
    while toks[i] == KEY:
        pairs[toks[i + 1]] = toks[i + 3]
        i += 5
    assert answers[0][1] == pairs[qkey]
    assert len(pairs) == n, "keys must be distinct"


@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 20))
@settings(max_examples=30, deadline=None)
def test_copy_answers_echo_sequence(seed, n):
    rng = np.random.default_rng(seed)
    toks, answers = corpus.gen_copy(rng, n)
    arrow = toks.index(ARROW)
    assert toks[2:arrow] == [t for _, t in answers]


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_all_tokens_in_vocab(seed):
    rng = np.random.default_rng(seed)
    toks, _ = corpus.sample_example(rng, 96)
    assert all(0 <= t < VOCAB for t in toks)


def test_batch_shapes_and_answer_weighting():
    rng = np.random.default_rng(0)
    x, mask = corpus.make_batch(rng, batch=4, seq_len=64)
    assert x.shape == (4, 64) and mask.shape == (4, 64)
    assert mask.max() == corpus.ANSWER_WEIGHT
    # no loss weight on/after padding
    for b in range(4):
        n = int((x[b] != 0).sum()) + int(x[b, 0] == 0)
        assert mask[b, max(0, n):].sum() == 0 or n >= 63
