"""ABI manifest consistency — the python↔rust contract.

The rust runtime builds positional args purely from *.inputs.json; these
tests pin the manifest's structure so a model.py refactor cannot silently
break the serving path.
"""

import numpy as np
import pytest

from compile.config import (
    CacheConfig, ModelConfig, default_variants, meta_dict, validate_variant,
)
from compile.model import (
    decode_input_manifest, param_spec, prefill_input_manifest,
)

MC = ModelConfig()
CC = CacheConfig()
VARIANTS = default_variants(MC)


def test_all_default_variants_validate():
    for v in VARIANTS:
        validate_variant(v, MC, CC)


def test_param_spec_leads_every_manifest():
    spec = param_spec(MC)
    for v in VARIANTS:
        m = decode_input_manifest(MC, CC, v)
        for (pname, pshape), (name, shape, dt) in zip(spec, m):
            assert name == pname
            assert tuple(pshape) == tuple(shape)
            assert dt == "f32"
    pm = prefill_input_manifest(MC, 128)
    assert [n for n, _, _ in pm[: len(spec)]] == [n for n, _ in spec]
    assert pm[-2][0] == "tokens" and pm[-1][0] == "length"


@pytest.mark.parametrize("vname", [v.name for v in VARIANTS])
def test_decode_manifest_shapes_are_consistent(vname):
    v = next(x for x in VARIANTS if x.name == vname)
    m = decode_input_manifest(MC, CC, v)
    b, c, r, g = CC.decode_batch, CC.capacity, CC.residual, CC.group
    hkv, dh = MC.n_kv_heads, MC.d_head
    by_name = {n: (shape, dt) for n, shape, dt in m}
    for l, (n16, n4, n2, vb) in enumerate(v.layers):
        if n16:
            assert by_name[f"l{l}.k16"][0] == (b, hkv, c, n16)
            assert by_name[f"l{l}.idx16"][1] == "i32"
        else:
            assert f"l{l}.k16" not in by_name
        if n4:
            assert by_name[f"l{l}.k4p"] == ((b, hkv, c, n4 // 2), "u8")
            assert by_name[f"l{l}.k4s"][0] == (b, hkv, c // g, n4)
        if n2:
            assert by_name[f"l{l}.k2p"] == ((b, hkv, c, n2 // 4), "u8")
        if vb == 16:
            assert by_name[f"l{l}.vfull"][0] == (b, hkv, c, dh)
            assert f"l{l}.vp" not in by_name
        else:
            assert by_name[f"l{l}.vp"] == ((b, hkv, c, dh * vb // 8), "u8")
            assert by_name[f"l{l}.vs"][0] == (b, hkv, c, dh // g)
        assert by_name[f"l{l}.kres"][0] == (b, hkv, r, dh)
        assert by_name[f"l{l}.vres"][0] == (b, hkv, r, dh)
    # tier channel counts partition d_head
    if v.layers[0][0] and v.layers[0][1] and v.layers[0][2]:
        n16, n4, n2, _ = v.layers[0]
        assert n16 + n4 + n2 == dh


def test_meta_dict_roundtrips_key_bits():
    meta = meta_dict(MC, CC, VARIANTS)
    by_name = {v["name"]: v for v in meta["variants"]}
    assert by_name["kv2"]["key_bits"] == 2.0
    assert by_name["mix30"]["key_bits"] == 3.0
    assert by_name["mix225"]["key_bits"] == 2.25
    assert abs(by_name["kvtuner"]["key_bits"] - 3.0) < 1e-9
    assert meta["cache"]["capacity"] % meta["cache"]["group"] == 0


def test_weights_bin_matches_param_spec_size():
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "weights.bin")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    total = sum(int(np.prod(s)) for _, s in param_spec(MC))
    assert os.path.getsize(path) == 4 * total
